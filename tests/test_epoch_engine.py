"""Epoch-engine semantics: consistency (Prop. 1), strategy equivalence,
termination latency, and indexed-frame determinism (§D.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.epoch import EpochConfig, run_virtual, run_worker
from repro.core.frames import (FrameStrategy, StateFrame,
                               sequential_collectives, shard_frame_pad)
from repro.core.stopping import HoeffdingCondition

N = 8  # frame size


def make_sample_fn(batch=4, n=N):
    """Each round adds `batch` Bernoulli samples per slot."""

    def sample_fn(key, carry):
        x = (jax.random.uniform(key, (batch, n)) < 0.3).astype(jnp.int32)
        return StateFrame(num=jnp.int32(batch), data=x.sum(0)), carry

    return sample_fn


def run(strategy, world, eps=0.05, seed=0, rounds=2):
    n = shard_frame_pad(N, world) if strategy == FrameStrategy.SHARED_FRAME \
        else N
    cond = HoeffdingCondition(eps=eps, delta=0.1)
    cfg = EpochConfig(strategy=strategy, rounds_per_epoch=rounds,
                      max_epochs=4000)
    sample_fn = make_sample_fn(n=n)
    template = jnp.zeros((n,), jnp.int32)
    if world == 1:
        return run_worker(sample_fn, cond, template, None,
                          jax.random.key(seed), cfg,
                          colls=sequential_collectives(),
                          seed_scalar=jnp.asarray(seed, jnp.uint32),
                          worker_id=jnp.int32(0))
    return run_virtual(sample_fn, cond, template, None, seed, world, cfg)


@pytest.mark.parametrize("strategy", list(FrameStrategy))
@pytest.mark.parametrize("world", [1, 4])
def test_all_strategies_stop_and_are_consistent(strategy, world):
    if strategy == FrameStrategy.LOCK and world > 1:
        pytest.skip("lock analog is the W=1 oracle")
    st = run(strategy, world)
    stop = np.asarray(st.stop).reshape(-1)[0]
    assert stop, "engine must stop once the Hoeffding bound holds"
    num = np.asarray(st.total.num).reshape(-1)[0]
    # consistency: the checked state is an integral number of whole rounds
    batch, rounds = 4, 2
    assert num % batch == 0
    # Hoeffding needs τ ≥ (1/2ε²)·log(2/δ) = 599.0 for ε=.05, δ=.1
    assert num >= 599
    # and the engine shouldn't have oversampled by more than the lag window:
    # one epoch of staleness × world × rounds × batch + one epoch
    assert num <= 599 + 2 * world * rounds * batch + world * rounds * batch


def test_epoch_lag_matches_paper():
    """LOCAL/SHARED check one epoch behind BARRIER (termination latency,
    App. C.3)."""
    st_b = run(FrameStrategy.BARRIER, 1)
    st_l = run(FrameStrategy.LOCAL_FRAME, 1)
    eb = int(np.asarray(st_b.stop_epoch))
    el = int(np.asarray(st_l.stop_epoch))
    assert el == eb + 1


def test_indexed_frame_deterministic_across_worlds():
    """§D.2: identical stopping point and state for any worker count."""
    results = {}
    for world in (1, 2, 4, 8):
        st = run(FrameStrategy.INDEXED_FRAME, world, seed=7)
        num = np.asarray(st.total.num).reshape(-1)[0]
        data = np.asarray(st.total.data)
        data = data[0] if data.ndim > 1 else data
        results[world] = (int(num), data.copy())
    nums = {w: r[0] for w, r in results.items()}
    assert len(set(nums.values())) == 1, f"τ* differs across worlds: {nums}"
    base = results[1][1]
    for w, (_, d) in results.items():
        np.testing.assert_array_equal(d, base)


def test_local_vs_shared_same_totals():
    """SHARED_FRAME holds shards of exactly the LOCAL_FRAME total."""
    st_l = run(FrameStrategy.LOCAL_FRAME, 4, seed=3)
    st_s = run(FrameStrategy.SHARED_FRAME, 4, seed=3)
    total_l = np.asarray(st_l.total.data)[0]
    total_s = np.asarray(st_s.total.data).reshape(-1)[:N]
    num_l = np.asarray(st_l.total.num)[0]
    num_s = np.asarray(st_s.total.num)[0]
    assert num_l == num_s
    np.testing.assert_array_equal(total_l, total_s)


def test_sequential_oracle_equals_barrier_w1():
    """BARRIER at W=1 checks every epoch = sequential Algorithm 1."""
    st = run(FrameStrategy.BARRIER, 1, seed=11)
    st2 = run(FrameStrategy.BARRIER, 1, seed=11)
    np.testing.assert_array_equal(np.asarray(st.total.data),
                                  np.asarray(st2.total.data))


@pytest.mark.parametrize("F", [1, 2, 4, 8])
def test_shared_frame_f_sweep(F):
    """Paper Fig. 3b semantics: any F divides the frame n/F per worker with
    identical results (groups hold redundant copies of the global sum)."""
    W = 8
    pad = shard_frame_pad(N, F)

    def sf(key, carry):
        x = (jax.random.uniform(key, (4, N)) < 0.5).astype(jnp.int32)
        return StateFrame(num=jnp.int32(4),
                          data=jnp.pad(x.sum(0), (0, pad - N))), carry

    cfg = EpochConfig(strategy=FrameStrategy.SHARED_FRAME,
                      rounds_per_epoch=2, max_epochs=2000)
    st = run_virtual(sf, HoeffdingCondition(eps=0.1, delta=0.1),
                     jnp.zeros((pad,), jnp.int32), None, 0, W, cfg,
                     frame_shards=F)
    assert bool(np.asarray(st.stop)[0])
    assert np.asarray(st.total.data).shape == (W, pad // F)
    # every group holds the same global shard content
    data = np.asarray(st.total.data)
    for g in range(1, W // F):
        np.testing.assert_array_equal(data[:F], data[g * F:(g + 1) * F])


def test_run_adaptive_facade():
    """Public API: all strategies through core.adaptive.run_adaptive."""
    from repro.core.adaptive import run_adaptive

    def sf(key, carry):
        x = (jax.random.uniform(key, (4, N)) < 0.4).astype(jnp.int32)
        return StateFrame(num=jnp.int32(4), data=x.sum(0)), carry

    for strategy in ("local", "shared", "indexed"):
        res = run_adaptive(sf, HoeffdingCondition(eps=0.1, delta=0.1),
                           jnp.zeros((N,), jnp.int32), strategy=strategy,
                           world=4, rounds_per_epoch=2)
        assert res.stopped
        assert res.num >= 149                  # Hoeffding τ for ε=.1, δ=.1
        assert res.data.shape == (N,)
        frac = res.data / res.num
        assert np.all((frac > 0.25) & (frac < 0.55))

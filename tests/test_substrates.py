"""Checkpointing (roundtrip, atomicity, elastic reshard), data determinism,
optimizer, compression, adaptive accumulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.data import DataCursor, TokenStream
from repro.optim import (AdamWConfig, AdaptiveAccumConfig, adamw_init,
                         adaptive_accumulate, cosine_schedule,
                         compressed_psum, dequantize_int8, quantize_int8)
from repro.optim.adamw import adamw_update


# ------------------------------------------------------------- checkpointing
def make_tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (10, 4)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = make_tree()
    save_checkpoint(tree, tmp_path, 5, meta={"x": 1}, chunks=3)
    restored, meta = load_checkpoint(tree, tmp_path, 5)
    assert meta == {"x": 1}
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_checkpoint_crc_detects_corruption(tmp_path):
    tree = make_tree()
    d = save_checkpoint(tree, tmp_path, 1, chunks=2)
    victim = next(p for p in d.iterdir() if p.suffix == ".npy")
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="CRC"):
        load_checkpoint(tree, tmp_path, 1)


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    tree = make_tree()
    save_checkpoint(tree, tmp_path, 3)
    # a stale tmp dir from a crashed writer must not count as a checkpoint
    (tmp_path / ".tmp_step_0000000009").mkdir()
    assert latest_step(tmp_path) == 3


def test_manager_async_and_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    tree = make_tree()
    for s in (1, 2, 3, 4):
        mgr.save(tree, s)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4]
    out = mgr.restore_latest(tree)
    assert out is not None and out[0] == 4


def test_elastic_reshard_roundtrip(tmp_path):
    """Save from one layout, restore onto a different (1-device) 'mesh' —
    exercises the global-slice chunk format."""
    tree = {"w": jnp.arange(24, dtype=jnp.float32).reshape(8, 3)}
    save_checkpoint(tree, tmp_path, 7, chunks=4)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = load_checkpoint(tree, tmp_path, 7, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# --------------------------------------------------------------------- data
def test_data_deterministic_and_resumable():
    s = TokenStream(vocab=100, seq_len=16, batch=8, seed=1)
    b1 = s.batch_at(jnp.int32(5))
    b2 = s.batch_at(jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = s.batch_at(jnp.int32(6))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifts
    cur = DataCursor(step=5, seed=1)
    assert DataCursor.from_meta(cur.as_meta()) == cur


def test_data_shard_count_independent():
    """Global stream at a step is invariant to the shard count."""
    s = TokenStream(vocab=1000, seq_len=8, batch=8, seed=3)
    full = np.asarray(s.batch_at(jnp.int32(2), 0, 1)["tokens"])
    halves = [np.asarray(s.batch_at(jnp.int32(2), i, 2)["tokens"])
              for i in (0, 1)]
    np.testing.assert_array_equal(full, np.concatenate(halves, axis=0))


# ---------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    params = {"w": jnp.ones((4,)) * 5.0}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(60):
        grads = {"w": params["w"] * 2.0}
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert np.abs(np.asarray(params["w"])).max() < 1.0


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.int32(0), peak=1.0, warmup=10,
                                 total=100)) == 0.0
    peak = float(cosine_schedule(jnp.int32(10), peak=1.0, warmup=10,
                                 total=100))
    end = float(cosine_schedule(jnp.int32(100), peak=1.0, warmup=10,
                                total=100))
    assert peak == pytest.approx(1.0)
    assert end == pytest.approx(0.1, abs=1e-3)


# -------------------------------------------------------------- compression
def test_int8_quantization_bounded_error():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, scale = quantize_int8(x, jax.random.key(1))
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 1.01


def test_compressed_psum_error_feedback_converges():
    """EF makes the *averaged* compression error vanish over steps."""
    W = 4
    g_true = jax.random.normal(jax.random.key(2), (W, 256))
    mean_true = np.asarray(g_true).mean(0)

    def worker(g, ef, key):
        return compressed_psum(g, ef, key, "w")

    ef = jnp.zeros((W, 256))
    acc = np.zeros(256)
    steps = 30
    for t in range(steps):
        keys = jax.random.split(jax.random.fold_in(jax.random.key(3), t), W)
        out, ef = jax.vmap(worker, axis_name="w")(g_true, ef, keys)
        acc += np.asarray(out)[0]
    # time-averaged reduced gradient ≈ true mean (EF unbiasedness)
    np.testing.assert_allclose(acc / steps, mean_true, atol=5e-3)


# ---------------------------------------------------- adaptive accumulation
def test_adaptive_accumulate_uses_more_micro_when_noisy():
    def grad_fn_factory(noise):
        def grad_fn(params, batch):
            g = {"w": params["w"] * 0.0 + 1.0 + noise * batch["eps"]}
            loss = jnp.float32(1.0)
            return loss, g
        return grad_fn

    params = {"w": jnp.ones((8,))}
    eps = jax.random.normal(jax.random.key(0), (16,))
    batches = {"eps": eps}
    cfg = AdaptiveAccumConfig(rtol=0.05, min_micro=2, max_micro=16)
    _, _, n_quiet, _ = adaptive_accumulate(grad_fn_factory(0.0), params,
                                           batches, cfg)
    _, _, n_noisy, _ = adaptive_accumulate(grad_fn_factory(2.0), params,
                                           batches, cfg)
    assert int(n_quiet) == 2
    assert int(n_noisy) > int(n_quiet)

"""Serving sessions: checkpoint-resume bit-identity for all 5 strategies,
stepping-path ≡ fused-while-loop equivalence, and elastic W→W′ re-sharding
of SHARED_FRAME sessions.

The acceptance obligations of the serving subsystem:

* interrupt ANY strategy mid-run at an epoch boundary, checkpoint, restore,
  continue → (τ, data, estimate) are **bit-identical** to the uninterrupted
  run (trivial for INDEXED_FRAME, and required for LOCAL/SHARED because
  frame snapshots are values, not memory);
* an elastic W→W′ resume of a SHARED_FRAME session (W′ | W) yields the same
  (τ, estimate) as the uninterrupted W-worker run, while per-worker shard
  memory drops to Θ(n/W′).
"""

import functools

import jax
import numpy as np
import pytest

from repro.core.adaptive import run_adaptive
from repro.core.frames import FrameStrategy
from repro.core.instances import get_instance
from repro.serve import (AdaptiveSession, SessionSpec, StepperCache,
                         reshard_session)

INSTANCE = "wrs"            # fast: stops within a handful of epochs
ELASTIC_INSTANCE = "reachability"   # ≥3 epochs at W=4 — real mid-run
# (substrate, world) cells every host can run; shard_map joins at W=1 on a
# single device (real-collective lowering; W>1 runs under the CI serve-smoke
# job's forced-8-device flags through benchmarks.bench_serve).
CELLS = [("sequential", 1), ("vmap", 2), ("shard_map", 1)]

CACHE = StepperCache()      # share compiled steppers across all tests


@functools.lru_cache(maxsize=None)
def reference(instance, strategy, world, substrate, seed=0):
    """Uninterrupted session run (same stepper via the shared cache)."""
    spec = SessionSpec(instance, strategy, world=world, seed=seed,
                       substrate=substrate)
    s = AdaptiveSession.create(spec, cache=CACHE).start().run()
    est, res = s.result()
    return est, res


def _raw(x):
    if hasattr(x, "dtype") and jax.dtypes.issubdtype(x.dtype,
                                                     jax.dtypes.prng_key):
        x = jax.random.key_data(x)
    return np.asarray(x)


def tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(_raw(x), _raw(y))


@pytest.mark.parametrize("substrate,world", CELLS)
@pytest.mark.parametrize("strategy", [s.value for s in FrameStrategy])
def test_checkpoint_resume_bit_identical(tmp_path, strategy, substrate,
                                         world):
    """Interrupt mid-run at an epoch boundary → restore → finish: every
    field of the result matches the uninterrupted run bit-for-bit."""
    est_ref, res_ref = reference(INSTANCE, strategy, world, substrate)
    assert res_ref.epochs >= 2, "need a genuine mid-run epoch boundary"

    spec = SessionSpec(INSTANCE, strategy, world=world, substrate=substrate)
    s = AdaptiveSession.create(spec, cache=CACHE).start()
    s.step()                              # mid-run epoch boundary
    assert not s.done
    s.save(tmp_path)

    r = AdaptiveSession.restore(tmp_path, cache=CACHE)
    assert r.epoch == s.epoch and r.tau == s.tau
    tree_equal(r.state, s.state)          # the full pytree round-trips
    r.run()
    est, res = r.result()
    assert res.num == res_ref.num
    assert res.epochs == res_ref.epochs
    np.testing.assert_array_equal(est, est_ref)
    tree_equal(res.data, res_ref.data)


@pytest.mark.parametrize("strategy", [s.value for s in FrameStrategy])
def test_session_matches_fused_run_adaptive(strategy):
    """The host-driven stepping path must agree bit-for-bit with the fused
    while_loop path (run_adaptive) — same τ, data, and estimate."""
    world = 2
    est_s, res_s = reference(INSTANCE, strategy, world, "vmap")
    built = get_instance(INSTANCE).build(
        world=world, strategy=FrameStrategy(strategy))
    res_f = run_adaptive(built.sample_fn, built.check_fn, built.template,
                         strategy=strategy, world=world, seed=0,
                         rounds_per_epoch=built.rounds_per_epoch,
                         max_epochs=built.max_epochs, substrate="vmap")
    assert res_s.num == res_f.num
    tree_equal(res_s.data, res_f.data)
    est_f = built.estimate(built.trim(res_f.data), float(res_f.num))
    np.testing.assert_array_equal(est_s, est_f)


def test_restore_needs_only_the_directory(tmp_path):
    """The manifest meta carries the full spec: restore without any
    session object in hand."""
    spec = SessionSpec(INSTANCE, "local", world=2, seed=3, substrate="vmap")
    s = AdaptiveSession.create(spec, cache=CACHE).start()
    s.step()
    s.save(tmp_path)
    r = AdaptiveSession.restore(tmp_path)
    assert r.spec == spec
    assert r.epoch == s.epoch


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        AdaptiveSession.restore(tmp_path)


def test_spec_validation():
    with pytest.raises(ValueError):
        SessionSpec(INSTANCE, "warp")
    with pytest.raises(ValueError):
        SessionSpec(INSTANCE, "shared", world=3, logical_world=4)
    with pytest.raises(ValueError):
        SessionSpec(INSTANCE, "local", world=2, logical_world=4)
    assert SessionSpec(INSTANCE, "shared", world=2, logical_world=4).fold == 2
    assert SessionSpec(INSTANCE, "shared", world=2).fold is None


# ------------------------------------------------------------------ elastic

@pytest.mark.parametrize("new_world", [2, 1])
def test_elastic_reshard_matches_uninterrupted(new_world):
    """SHARED_FRAME W=4 → W′ resume: identical (τ, estimate, data) to the
    uninterrupted W=4 run, with per-worker shards of n/W′."""
    est_ref, res_ref = reference(ELASTIC_INSTANCE, "shared", 4, "vmap")

    spec = SessionSpec(ELASTIC_INSTANCE, "shared", world=4, substrate="vmap")
    s = AdaptiveSession.create(spec, cache=CACHE).start()
    s.step()                               # mid-run
    assert not s.done
    r = reshard_session(s, new_world, cache=CACHE)
    assert r.spec.world == new_world and r.spec.logical_world == 4
    # Θ(n/W′): each physical worker now holds 1/W′ of every vector leaf
    for leaf, old in zip(jax.tree.leaves(r.state.total.data),
                         jax.tree.leaves(s.state.total.data)):
        a, o = np.asarray(leaf), np.asarray(old)
        if o.ndim > 1:                     # vector leaves: (4, n/4) → (W′, n/W′)
            assert a.shape == (new_world, o.shape[1] * 4 // new_world)
    r.run()
    est, res = r.result()
    assert res.num == res_ref.num
    np.testing.assert_array_equal(est, est_ref)
    tree_equal(res.data, res_ref.data)


def test_elastic_chain_reshard():
    """4 → 2 → 1 re-shard chain continues the identical trajectory."""
    est_ref, res_ref = reference(ELASTIC_INSTANCE, "shared", 4, "vmap")
    s = AdaptiveSession.create(
        SessionSpec(ELASTIC_INSTANCE, "shared", world=4, substrate="vmap"),
        cache=CACHE).start()
    s.step()
    mid = reshard_session(s, 2, cache=CACHE)
    if not mid.done:
        mid.step()
    final = reshard_session(mid, 1, cache=CACHE)
    final.run()
    est, res = final.result()
    assert res.num == res_ref.num
    np.testing.assert_array_equal(est, est_ref)


def test_elastic_checkpoint_roundtrip(tmp_path):
    """A folded (resharded) session checkpoints and restores like any
    other — the spec's logical_world makes the layout self-describing."""
    est_ref, res_ref = reference(ELASTIC_INSTANCE, "shared", 4, "vmap")
    s = AdaptiveSession.create(
        SessionSpec(ELASTIC_INSTANCE, "shared", world=4, substrate="vmap"),
        cache=CACHE).start()
    s.step()
    r = reshard_session(s, 2, cache=CACHE)
    r.save(tmp_path)
    r2 = AdaptiveSession.restore(tmp_path, cache=CACHE)
    assert r2.spec.fold == 2
    r2.run()
    est, res = r2.result()
    assert res.num == res_ref.num
    np.testing.assert_array_equal(est, est_ref)


def test_elastic_rejects_invalid():
    s = AdaptiveSession.create(
        SessionSpec(INSTANCE, "local", world=2, substrate="vmap"),
        cache=CACHE).start()
    with pytest.raises(ValueError, match="SHARED_FRAME"):
        reshard_session(s, 1)
    sh = AdaptiveSession.create(
        SessionSpec(ELASTIC_INSTANCE, "shared", world=4, substrate="vmap"),
        cache=CACHE)
    with pytest.raises(ValueError, match="no state"):
        reshard_session(sh, 2)
    sh.start()
    with pytest.raises(ValueError, match="divide"):
        reshard_session(sh, 3)

"""Stopping conditions: bound shapes, monotonicity, and (ε,δ) coverage
(property-based)."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.frames import StateFrame
from repro.core.stopping import (EmpiricalBernsteinCondition,
                                 HoeffdingCondition, KadabraCondition,
                                 kadabra_omega)


def test_kadabra_bounds_nonnegative_and_decreasing():
    cond = KadabraCondition(eps=0.05, delta=0.1, omega=10_000, n_vertices=50)
    b = jnp.linspace(0.0, 1.0, 50)
    f1, g1 = cond.per_vertex_bounds(b, jnp.float32(100.0))
    f2, g2 = cond.per_vertex_bounds(b, jnp.float32(1000.0))
    assert np.all(np.asarray(f1) >= 0) and np.all(np.asarray(g1) >= 0)
    # both bounds shrink with more samples
    assert np.all(np.asarray(f2) <= np.asarray(f1) + 1e-7)
    assert np.all(np.asarray(g2) <= np.asarray(g1) + 1e-7)
    # f,g grow with b̃ (paper App. B)
    assert np.all(np.diff(np.asarray(f2)) >= -1e-7)
    assert np.all(np.diff(np.asarray(g2)) >= -1e-7)


def test_kadabra_stops_at_omega():
    cond = KadabraCondition(eps=0.001, delta=0.1, omega=500, n_vertices=10)
    frame = StateFrame(num=jnp.int32(500), data=jnp.ones((10,), jnp.int32) * 250)
    stop, aux = cond(frame)
    assert bool(stop)


def test_omega_formula():
    w = kadabra_omega(0.05, 0.1, vd_upper=20)
    assert 1_000 < w < 3_000  # (0.5/0.0025)·(4+1+2.30) ≈ 1461


@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 0.3), st.floats(0.05, 0.3))
def test_hoeffding_threshold(eps, delta):
    cond = HoeffdingCondition(eps=eps, delta=delta)
    need = np.log(2.0 / delta) / (2 * eps * eps)
    below = StateFrame(num=jnp.int32(int(need * 0.9)),
                       data=jnp.zeros((), jnp.float32))
    above = StateFrame(num=jnp.int32(int(need * 1.1) + 2),
                       data=jnp.zeros((), jnp.float32))
    assert not bool(cond(below)[0])
    assert bool(cond(above)[0])


def test_empirical_bernstein_coverage():
    """(ε,δ)-coverage on Bernoulli streams: the stopped estimate must be
    within ε of the true mean in ≥ (1−δ) of trials."""
    rng = np.random.default_rng(0)
    eps, delta, p = 0.05, 0.1, 0.3
    cond = EmpiricalBernsteinCondition(eps=eps, delta=delta, value_range=1.0)
    failures = 0
    trials = 40
    for t in range(trials):
        s1 = s2 = 0.0
        n = 0
        while True:
            x = float(rng.random() < p)
            s1 += x
            s2 += x * x
            n += 1
            frame = StateFrame(num=jnp.int32(n),
                               data={"s1": jnp.float32(s1),
                                     "s2": jnp.float32(s2)})
            stop, aux = cond(frame)
            if bool(stop) or n > 20_000:
                break
        if abs(s1 / n - p) > eps:
            failures += 1
    assert failures / trials <= delta + 0.05, f"{failures}/{trials} misses"

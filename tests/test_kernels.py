"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret=True executes the Pallas body in python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ref
from repro.kernels.bfs_frontier import bfs_frontier
from repro.kernels.flash_attention import flash_attention
from repro.kernels.frame_accum import frame_accum
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssm_scan import ssm_scan


# ---------------------------------------------------------------- frame_accum
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("w,n", [(1, 64), (4, 1000), (16, 257), (3, 8192)])
def test_frame_accum_sweep(dtype, w, n):
    key = jax.random.key(w * n)
    if dtype == jnp.int32:
        fr = jax.random.randint(key, (w, n), 0, 100, jnp.int32)
    else:
        fr = jax.random.normal(key, (w, n), jnp.float32).astype(dtype)
    got = frame_accum(fr, block_n=256, interpret=True)
    exp = ref.frame_accum_ref(fr)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 9), st.integers(1, 300))
def test_frame_accum_property(w, n):
    fr = jnp.arange(w * n, dtype=jnp.int32).reshape(w, n) % 97
    got = frame_accum(fr, block_n=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(fr).sum(0))


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,hd,window", [
    (1, 4, 2, 128, 64, 0),
    (2, 4, 4, 256, 32, 0),     # MHA (kv = h)
    (1, 8, 1, 128, 64, 0),     # MQA
    (1, 4, 2, 256, 64, 64),    # sliding window
])
def test_flash_attention_sweep(dtype, b, h, kv, s, hd, window):
    ks = jax.random.split(jax.random.key(s + h), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol, rtol=tol)


# -------------------------------------------------------------------- scans
@pytest.mark.parametrize("b,s,d,n", [(1, 32, 64, 4), (2, 128, 256, 16),
                                     (1, 17, 64, 8)])
def test_ssm_scan_sweep(b, s, d, n):
    ks = jax.random.split(jax.random.key(s), 2)
    a = jax.random.uniform(ks[0], (b, s, d, n), minval=0.1, maxval=0.99)
    bb = jax.random.normal(ks[1], (b, s, d, n))
    got = ssm_scan(a, bb, block_d=64, interpret=True)
    exp = ref.ssm_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("b,s,w", [(1, 64, 512), (2, 33, 1024)])
def test_rglru_scan_sweep(b, s, w):
    ks = jax.random.split(jax.random.key(w), 2)
    a = jax.random.uniform(ks[0], (b, s, w), minval=0.2, maxval=0.95)
    bb = jax.random.normal(ks[1], (b, s, w))
    got = rglru_scan(a, bb, block_w=256, interpret=True)
    exp = ref.rglru_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_scan_kernel_matches_sequential_recurrence():
    """Ground truth: explicit python recurrence."""
    a = jnp.array([[[0.5], [0.25], [0.75]]])  # (1,3,1)
    b = jnp.array([[[1.0], [2.0], [4.0]]])
    got = np.asarray(rglru_scan(a, b, block_w=1, interpret=True))[0, :, 0]
    h = 0.0
    exp = []
    for t in range(3):
        h = float(a[0, t, 0]) * h + float(b[0, t, 0])
        exp.append(h)
    np.testing.assert_allclose(got, exp, rtol=1e-6)


# ------------------------------------------------------------- bfs_frontier
@pytest.mark.parametrize("n,m,seed", [(50, 120, 0), (200, 600, 1)])
def test_bfs_frontier_sweep(n, m, seed):
    from repro.graphs import erdos_renyi
    g = erdos_renyi(n, m, seed=seed)
    ks = jax.random.split(jax.random.key(seed), 2)
    sigma = jax.random.uniform(ks[0], (n,))
    dist = jax.random.randint(ks[1], (n,), 0, 6, jnp.int32)
    for level in (0, 2, 5):
        got = bfs_frontier(g.src, g.dst, sigma, dist, jnp.int32(level),
                           block_e=64, interpret=True)
        exp = ref.bfs_frontier_ref(g.src, g.dst, sigma, dist,
                                   jnp.int32(level))
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=1e-5, rtol=1e-5)

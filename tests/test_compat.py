"""JAX version-compat resolvers (`repro.core.compat`): both branches of
every resolver — new API present vs. absent (via monkeypatch) — so jax
version drift fails loudly here instead of deep inside the engine."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.compat as compat


# ------------------------------------------------------------------ shard_map
def test_resolve_shard_map_new_api(monkeypatch):
    def fake(f, **kw):
        return f

    monkeypatch.setattr(jax, "shard_map", fake, raising=False)
    sm, kwarg = compat._resolve_shard_map()
    assert sm is fake and kwarg == "check_vma"


def test_resolve_shard_map_old_api(monkeypatch):
    monkeypatch.delattr(jax, "shard_map", raising=False)
    from jax.experimental.shard_map import shard_map as old
    sm, kwarg = compat._resolve_shard_map()
    assert sm is old and kwarg == "check_rep"


def test_shard_map_wrapper_maps_check_kwarg(monkeypatch):
    recorded = {}

    def fake(f, *, mesh, in_specs, out_specs, **kw):
        recorded.clear()
        recorded.update(kw)
        return f

    monkeypatch.setattr(compat, "_SHARD_MAP", fake)
    monkeypatch.setattr(compat, "_CHECK_KWARG", "check_rep")
    compat.shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=())
    assert recorded == {"check_rep": True}
    monkeypatch.setattr(compat, "_CHECK_KWARG", "check_vma")
    compat.shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=(),
                     check_vma=False)
    assert recorded == {"check_vma": False}


def test_shard_map_real_resolution_importable():
    """Whatever this jax ships, the module-level resolution must be a
    callable plus one of the two known kwarg spellings."""
    assert callable(compat._SHARD_MAP)
    assert compat._CHECK_KWARG in ("check_vma", "check_rep")


# ------------------------------------------------------------------ axis_size
def test_axis_size_new_api(monkeypatch):
    monkeypatch.setattr(jax.lax, "axis_size",
                        lambda name: ("size-of", name), raising=False)
    assert compat.axis_size("i") == ("size-of", "i")


def test_axis_size_psum_fallback(monkeypatch):
    monkeypatch.delattr(jax.lax, "axis_size", raising=False)
    out = jax.vmap(lambda x: compat.axis_size("i") * x, axis_name="i")(
        jnp.ones((4,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), np.full(4, 4))


# ------------------------------------------------------- AxisType / make_mesh
class _FakeAxisType:
    Auto = "auto"


def _recording_make_mesh(recorded):
    def fake(shape, axes, **kw):
        recorded.clear()
        recorded.update(shape=shape, axes=axes, **kw)
        return "mesh"
    return fake


def test_make_mesh_with_axis_type(monkeypatch):
    recorded = {}
    monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType,
                        raising=False)
    monkeypatch.setattr(jax, "make_mesh", _recording_make_mesh(recorded))
    assert compat.make_mesh((2, 1), ("a", "b")) == "mesh"
    assert recorded["axis_types"] == ("auto", "auto")


def test_make_mesh_axis_type_present_but_disabled(monkeypatch):
    recorded = {}
    monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType,
                        raising=False)
    monkeypatch.setattr(jax, "make_mesh", _recording_make_mesh(recorded))
    compat.make_mesh((2,), ("a",), auto_axis_types=False)
    assert "axis_types" not in recorded


def test_make_mesh_without_axis_type(monkeypatch):
    recorded = {}
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    monkeypatch.setattr(jax, "make_mesh", _recording_make_mesh(recorded))
    compat.make_mesh((2,), ("a",))
    assert recorded == {"shape": (2,), "axes": ("a",)}


def test_make_mesh_real_jax():
    mesh = compat.make_mesh((1,), ("x",))
    assert dict(mesh.shape) == {"x": 1}


# ------------------------------------------------------------------- set_mesh
def test_set_mesh_resolution_order(monkeypatch):
    monkeypatch.setattr(jax, "set_mesh", lambda m: ("new", m), raising=False)
    assert compat.set_mesh("M") == ("new", "M")
    monkeypatch.delattr(jax, "set_mesh", raising=False)
    monkeypatch.setattr(jax.sharding, "use_mesh", lambda m: ("use", m),
                        raising=False)
    assert compat.set_mesh("M") == ("use", "M")
    monkeypatch.delattr(jax.sharding, "use_mesh", raising=False)
    # oldest fallback: the Mesh object itself is the context manager
    assert compat.set_mesh("M") == "M"


# -------------------------------------------------------------- cost_analysis
def test_cost_analysis_shapes():
    class Compiled:
        def __init__(self, ca):
            self._ca = ca

        def cost_analysis(self):
            return self._ca

    assert compat.cost_analysis(Compiled({"flops": 2.0})) == {"flops": 2.0}
    assert compat.cost_analysis(Compiled([{"flops": 3.0}])) == {"flops": 3.0}
    assert compat.cost_analysis(Compiled([])) == {}
    assert compat.cost_analysis(Compiled(None)) == {}

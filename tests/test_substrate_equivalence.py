"""Substrate equivalence: sequential / vmap / shard_map executions of the
epoch engine must agree bit-for-bit on every (instance, strategy, W, F) cell.

Three layers of coverage:

* In-process grid over every registered instance at the world sizes this
  host can actually cross-check (W=1 everywhere — sequential, vmap, and a
  1-device shard_map mesh; larger W joins when the process has ≥ W devices,
  i.e. under the CI substrate job's
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
* A subprocess that forces 8 host devices and runs the grouped F < W cells
  under real shard_map collectives — so the single-device fast tier still
  exercises grouped reduce-scatter + cross-group all-reduce on every run.
* A lowering check (in the same subprocess) that the shard_map F < W path
  emits a real grouped ``reduce_scatter`` — not the vmap psum+slice
  reference form.
"""

import functools
import os
import subprocess
import sys
from pathlib import Path

import jax
import pytest

from repro.core.conformance import (EQUIVALENCE_WORLDS, equivalence_grid,
                                    run_substrate_equivalence)
from repro.core.frames import FrameStrategy
from repro.core.substrate import (Substrate, available_substrates,
                                  unavailable_reason)

ROOT = Path(__file__).resolve().parents[1]
INSTANCES = ("kadabra", "triangles", "reachability", "wrs", "diameter",
             "gradvar")

# Only sweep worlds this process can cross-check on ≥ 2 substrates: W=1
# always; W>1 joins when shard_map has enough devices (the CI substrate job
# forces 8).  Running vmap-only cells would compare nothing.
WORLDS = tuple(w for w in EQUIVALENCE_WORLDS
               if w == 1 or len(jax.devices()) >= w)
REQUIRE_ALL = os.environ.get("SUBSTRATE_REQUIRE_ALL", "") == "1"


@functools.lru_cache(maxsize=None)
def report(name):
    return run_substrate_equivalence(name, worlds=WORLDS,
                                     require_all=REQUIRE_ALL)


def test_substrate_enum_availability():
    assert unavailable_reason(Substrate.VMAP, 8) is None
    assert unavailable_reason(Substrate.SEQUENTIAL, 2) is not None
    assert Substrate.SEQUENTIAL in available_substrates(1)
    assert Substrate.VMAP in available_substrates(64)
    many = len(jax.devices()) + 1
    assert Substrate.SHARD_MAP not in available_substrates(many)


def test_equivalence_grid_shape():
    cells = equivalence_grid((1, 2, 4, 8))
    assert len(cells) == len(FrameStrategy) * 4 + 3  # + SHARED F=W/2 cells
    assert (FrameStrategy.SHARED_FRAME, 8, 4) in cells
    assert (FrameStrategy.SHARED_FRAME, 1, 0) in cells


@pytest.mark.parametrize("world", WORLDS)
@pytest.mark.parametrize("strategy", list(FrameStrategy),
                         ids=lambda s: s.name)
@pytest.mark.parametrize("instance", INSTANCES)
def test_cell_bit_identical_across_substrates(instance, strategy, world):
    rep = report(instance)
    cells = [c for c in rep.cells
             if c.strategy == strategy and c.world == world]
    assert cells, "grid must cover the cell"
    for cell in cells:  # includes the SHARED F=W/2 cell where it exists
        assert cell.ok, "\n".join(cell.failures)
        assert cell.compared >= (1 if world == 1 else 0)


@pytest.mark.parametrize("instance", INSTANCES)
def test_w1_oracle_joins_comparison(instance):
    """At W=1 all three substrates run and agree (the sequential oracle is
    part of the comparison, not just vmap vs vmap)."""
    rep = report(instance)
    for cell in rep.cells:
        if cell.world != 1:
            continue
        assert "sequential" in cell.ran and "vmap" in cell.ran
        assert "shard_map" in cell.ran  # 1-device mesh works everywhere
        assert cell.ok, "\n".join(cell.failures)


# --------------------------------------------------------------- subprocess
# Real grouped collectives need >1 device; force 8 virtual host devices in a
# child process (the flag must precede the first jax import and must not
# leak into this one — see tests/test_system.py).

_GROUPED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
assert len(jax.devices()) == 8

from repro.core.conformance import run_substrate_equivalence
from repro.core.frames import FrameStrategy

rep = run_substrate_equivalence(
    "reachability",
    strategies=[FrameStrategy.LOCAL_FRAME, FrameStrategy.SHARED_FRAME],
    worlds=(4,), require_all=True)
print(rep.summary())
assert rep.ok, rep.failures
cells = {(c.strategy, c.world, c.frame_shards): c for c in rep.cells}
grouped = cells[(FrameStrategy.SHARED_FRAME, 4, 2)]
assert "shard_map" in grouped.ran and "vmap" in grouped.ran

# Lowering proof: the F < W shard_map path must emit a grouped
# reduce-scatter (axis_index_groups), not the psum+slice reference form.
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.core.frames import StateFrame, axis_collectives
from repro.core.substrate import worker_mesh

mesh = worker_mesh(4)
colls = axis_collectives("workers", 4, frame_shards=2, grouped=True)

def scatter(x):
    f = StateFrame(num=jnp.int32(1), data=x[0])
    out = colls.scatter_frames(f)
    return out.data[None]

fn = shard_map(scatter, mesh=mesh, in_specs=P("workers"),
               out_specs=P("workers"), check_vma=False)
text = jax.jit(fn).lower(jnp.zeros((4, 8), jnp.int32)).as_text()
assert "reduce_scatter" in text, "grouped path must lower to reduce_scatter"
print("GROUPED_SUBSTRATE_OK")
"""


@pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="grouped F<W lowering needs ≥4 devices (CI substrate job)")
def test_grouped_lowering_emits_reduce_scatter():
    """In-process version of the subprocess lowering proof: the shard_map
    F < W path must be the grouped reduce-scatter, not psum+slice."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    from repro.core.frames import StateFrame, axis_collectives
    from repro.core.substrate import worker_mesh

    mesh = worker_mesh(4)
    colls = axis_collectives("workers", 4, frame_shards=2, grouped=True)

    def scatter(x):
        out = colls.scatter_frames(StateFrame(num=jnp.int32(1), data=x[0]))
        return out.data[None]

    fn = shard_map(scatter, mesh=mesh, in_specs=P("workers"),
                   out_specs=P("workers"), check_vma=False)
    text = jax.jit(fn).lower(jnp.zeros((4, 8), jnp.int32)).as_text()
    assert "reduce_scatter" in text


@pytest.mark.skipif(
    len(jax.devices()) >= 8,
    reason="parent already runs the grouped W>1 cells in-process (CI "
           "substrate-shardmap job) — the subprocess would just repeat them")
def test_grouped_collectives_under_forced_multidevice():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _GROUPED_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600, cwd=ROOT)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "GROUPED_SUBSTRATE_OK" in r.stdout

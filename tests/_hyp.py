"""Optional-dependency shim for ``hypothesis``.

Property-based tests import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly.  When hypothesis is installed this module
is a transparent re-export; when it is missing, the decorators degrade to a
runtime ``pytest.skip`` so the *module still collects* and its non-property
tests run everywhere.
"""

from __future__ import annotations

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        # NB: the replacement takes NO arguments (the originals' parameters
        # are hypothesis-drawn, not fixtures) so pytest collects it cleanly.
        def deco(fn):
            def wrapper():
                pytest.skip("hypothesis not installed")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

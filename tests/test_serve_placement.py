"""Placement-aware serving: disjoint-submesh scheduling, the stepper-cache
placement key, pressure-driven elasticity, and placement-aware resume.

The acceptance obligations of this layer:

* two same-shape W=4 sessions running **concurrently** on disjoint
  submeshes of 8 forced host devices each produce (τ, estimate)
  bit-identical to the same session run alone on ``jax.devices()[:4]``;
* a pressure-triggered (scheduler-initiated) reshard W=4 → 2 mid-stream
  stays bit-identical to the uninterrupted W=4 run;
* two same-shape sessions on different submeshes get **distinct** compiled
  stepper-cache entries (a shape-keyed cache would silently run one session
  on the other's devices).

The pool accounts in worker slots, so everything scheduler-level is also
exercised in-process on a 1-device host with vmap sessions over an abstract
topology; the shard_map cells run in a forced-8-device subprocess (or
in-process under the CI ``serve-placement`` job's XLA flags).
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.serve import (AdaptiveSession, DevicePool, EpochScheduler,
                         PressurePolicy, SessionSpec, StepperCache)

ROOT = Path(__file__).resolve().parents[1]

SHARED4 = SessionSpec("reachability", "shared", world=4, substrate="vmap")


def _solo(spec: SessionSpec):
    s = AdaptiveSession.create(spec).start().run()
    est, res = s.result()
    return np.asarray(est), res


# ------------------------------------------------------- spec / cache keying

def test_spec_placement_validation_and_meta_roundtrip():
    spec = SessionSpec("wrs", "shared", world=2, substrate="shard_map",
                       placement=[3, 5])
    assert spec.placement == (3, 5)            # normalized to a tuple
    back = SessionSpec.from_meta(json.loads(json.dumps(spec.as_meta())))
    assert back == spec                        # JSON round-trip (checkpoint)
    with pytest.raises(ValueError, match="shard_map"):
        SessionSpec("wrs", "shared", world=2, substrate="vmap",
                    placement=(0, 1))
    with pytest.raises(ValueError, match="device"):
        SessionSpec("wrs", "shared", world=2, substrate="shard_map",
                    placement=(0, 1, 2))


def test_stepper_key_distinguishes_placements():
    """Satellite regression: the compiled-stepper cache key must include the
    mesh device ids (and axis name), not just the session shape."""
    a = SessionSpec("wrs", "shared", world=1, substrate="shard_map",
                    placement=(0,))
    b = SessionSpec("wrs", "shared", world=1, substrate="shard_map")
    c = SessionSpec("wrs", "shared", world=1, substrate="shard_map",
                    placement=(0,))
    assert a.stepper_key() != b.stepper_key()
    assert a.stepper_key() == c.stepper_key()
    from repro.core.substrate import WORKER_AXIS
    assert WORKER_AXIS in a.stepper_key()


def test_stepper_cache_separates_same_shape_on_different_submeshes():
    """Two same-shape sessions pinned to different (1-device) submeshes get
    distinct cache entries and both produce the solo result.  (W>1 disjoint
    submeshes run under the forced-8-device subprocess below.)"""
    est_ref, res_ref = _solo(SessionSpec("wrs", "shared", world=1,
                                         substrate="shard_map"))
    cache = StepperCache()
    dev0 = jax.devices()[0].id
    a = AdaptiveSession.create(
        SessionSpec("wrs", "shared", world=1, substrate="shard_map",
                    placement=(dev0,)), cache=cache)
    b = AdaptiveSession.create(
        SessionSpec("wrs", "shared", world=1, substrate="shard_map"),
        cache=cache)
    assert len(cache) == 2      # pinned vs unpinned must not share
    for s in (a, b):
        s.start().run()
        est, res = s.result()
        assert res.num == res_ref.num
        np.testing.assert_array_equal(est, est_ref)


def test_worker_mesh_builds_on_arbitrary_device_subset():
    """Placement leases are not leading-device prefixes; the mesh
    constructor must take any explicit subset (and expose its ids for
    cache keying)."""
    from repro.core.substrate import mesh_device_ids, worker_mesh
    sub = jax.devices()[-1:]          # non-leading whenever the host has >1
    mesh = worker_mesh(1, devices=sub)
    assert mesh_device_ids(mesh) == (sub[0].id,)
    with pytest.raises(ValueError, match="exactly"):
        worker_mesh(2, devices=sub)


# ------------------------------------------------- scheduler admission stage

def test_admission_leases_disjoint_submeshes_and_releases_on_retire():
    pool = DevicePool(8)
    sched = EpochScheduler(max_in_flight=4, pool=pool)
    sched.submit(SHARED4, qid="a")
    sched.submit(dataclass_replace_seed(SHARED4, 1), qid="b")
    sched.tick()                   # both run ≥ 2 epochs → still leased
    leases = {qid: lease.ids for qid, lease in sched._leases.items()}
    assert set(leases) == {"a", "b"}
    assert set(leases["a"]).isdisjoint(leases["b"])
    assert pool.free == 0
    sched.drain()
    assert pool.free == 8       # every lease released at retirement
    assert sched.results["a"].devices_leased == 4
    assert sched.results["b"].devices_leased == 4


def test_admission_queues_on_placement_wait_and_accounts_it():
    """A full pool (not max_in_flight) is what blocks here — the query's
    placement_wait_ticks must record that."""
    pool = DevicePool(4)
    sched = EpochScheduler(max_in_flight=8, pool=pool)
    sched.submit(SHARED4, qid="first")
    sched.submit(SessionSpec("wrs", "local", world=2, substrate="vmap"),
                 qid="second")
    sched.tick()
    assert sched.in_flight == 1 and sched.pending == 1
    sched.drain()
    r = sched.results["second"]
    assert r.placement_wait_ticks >= 1
    assert r.placement_wait_ticks <= r.wait_ticks
    # without a pool the column is identically 0
    plain = EpochScheduler(max_in_flight=1)
    plain.submit(SessionSpec("wrs", "local", world=2, substrate="vmap"),
                 qid="q")
    plain.drain()
    assert plain.results["q"].placement_wait_ticks == 0
    assert plain.results["q"].devices_leased == 0


def test_results_bit_identical_to_solo_under_pool():
    """Leasing/placement must not perturb any query's trajectory."""
    est_ref, res_ref = _solo(SHARED4)
    pool = DevicePool(8)
    sched = EpochScheduler(max_in_flight=4, pool=pool)
    sched.submit(SHARED4, qid="a")
    sched.submit(dataclass_replace_seed(SHARED4, 1), qid="b")
    sched.drain()
    r = sched.results["a"]
    assert r.tau == res_ref.num
    np.testing.assert_array_equal(r.estimate, est_ref)


def dataclass_replace_seed(spec, seed):
    import dataclasses
    return dataclasses.replace(spec, seed=seed)


def test_submit_rejects_query_wider_than_pool():
    sched = EpochScheduler(pool=DevicePool(2))
    with pytest.raises(ValueError, match="never"):
        sched.submit(SHARED4)


def test_pressure_policy_requires_pool():
    with pytest.raises(ValueError, match="pool"):
        EpochScheduler(pressure=PressurePolicy())


# --------------------------------------------------------- pressure elasticity

def test_pressure_shrink_admits_queued_query_and_stays_bit_identical():
    """Scheduler-initiated SHARED_FRAME shrink: queued demand exceeds free
    devices → the widest shared session halves, the queued query admits,
    and the shrunk session's (τ, estimate) is bit-identical to the
    uninterrupted W=4 run — the PR-4 elastic certification extended to
    reshards the *scheduler* decides on."""
    est_ref, res_ref = _solo(SHARED4)
    pool = DevicePool(4)
    sched = EpochScheduler(max_in_flight=4, pool=pool,
                           pressure=PressurePolicy(min_world=1))
    sched.submit(SHARED4, qid="A")
    sched.submit(SessionSpec("wrs", "local", world=2, substrate="vmap"),
                 qid="B")
    events = sched.drain()
    reshards = [e for ev in events for e in ev.resharded]
    assert ("A", 4, 2) in reshards
    admit_tick = {qid: ev.tick for ev in events for qid in ev.admitted}
    shrink_tick = next(ev.tick for ev in events if ev.resharded)
    assert admit_tick["B"] == shrink_tick     # the shrink freed B's slots
    rA = sched.results["A"]
    assert rA.spec.world == 2 and rA.devices_leased == 4
    assert rA.tau == res_ref.num
    np.testing.assert_array_equal(rA.estimate, est_ref)


def test_pressure_shrink_respects_min_world_and_strategy():
    """LOCAL sessions never shrink; min_world floors the halving."""
    pool = DevicePool(4)
    sched = EpochScheduler(max_in_flight=4, pool=pool,
                           pressure=PressurePolicy(min_world=4))
    sched.submit(SHARED4, qid="A")           # min_world=4 → cannot halve
    sched.submit(SessionSpec("wrs", "local", world=2, substrate="vmap"),
                 qid="B")
    events = sched.drain()
    assert not any(ev.resharded for ev in events)
    assert sched.results["A"].spec.world == 4
    assert sched.results["B"].placement_wait_ticks >= 1


def test_pressure_regrow_on_drained_queue_stays_bit_identical():
    """A shrunk session grows back toward its logical width once the queue
    drains and devices free up — still bit-identical to the solo run."""
    est_ref, res_ref = _solo(SHARED4)
    pool = DevicePool(4)
    sched = EpochScheduler(max_in_flight=4, pool=pool,
                           pressure=PressurePolicy(min_world=1, regrow=True))
    sched.submit(SHARED4, qid="A")
    sched.tick()                              # A leased 4, one epoch in
    assert not sched._active["A"].done
    sched._resize("A", 2)                     # as if an earlier tick shrank
    assert pool.free == 2
    events = sched.drain()
    reshards = [e for ev in events for e in ev.resharded]
    assert ("A", 2, 4) in reshards            # the regrow event
    rA = sched.results["A"]
    assert rA.spec.world == 4
    assert rA.tau == res_ref.num
    np.testing.assert_array_equal(rA.estimate, est_ref)


def test_no_regrow_when_policy_disables_it():
    pool = DevicePool(4)
    sched = EpochScheduler(max_in_flight=4, pool=pool,
                           pressure=PressurePolicy(min_world=1,
                                                   regrow=False))
    sched.submit(SHARED4, qid="A")
    sched.tick()
    if sched._active["A"].done:               # paranoia: needs a mid-run
        pytest.skip("session too short to exercise regrow")
    sched._resize("A", 2)
    events = sched.drain()
    assert not any(ev.resharded for ev in events)
    assert sched.results["A"].spec.world == 2


# ------------------------------------------------------- checkpoint + resume

def test_scheduler_resume_with_pool_releases_and_reacquires(tmp_path):
    """Preempt a pool-backed scheduler, resume with a *fresh* pool: leases
    are re-acquired at admission and the results match the uninterrupted
    reference bit-for-bit."""
    est_ref, res_ref = _solo(SHARED4)
    sched = EpochScheduler(max_in_flight=2, pool=DevicePool(8),
                           checkpoint_dir=tmp_path)
    sched.submit(SHARED4, qid="A")
    sched.submit(SessionSpec("wrs", "local", world=2, substrate="vmap"),
                 qid="B")
    sched.tick()
    sched.save_all()
    resumed = EpochScheduler.resume(tmp_path, max_in_flight=2,
                                    pool=DevicePool(8))
    resumed.drain()
    assert set(resumed.results) == {"A", "B"}
    rA = resumed.results["A"]
    assert rA.tau == res_ref.num
    np.testing.assert_array_equal(rA.estimate, est_ref)
    assert rA.devices_leased == 4
    assert resumed.pool.free == 8


def test_resume_skips_queries_wider_than_pool_without_aborting(tmp_path):
    """A checkpointed W=4 session resumed onto a 2-slot pool cannot ever be
    placed; resume() must skip it loudly (warning + sched.unresumed) and
    still restore everything that fits."""
    sched = EpochScheduler(max_in_flight=4, pool=DevicePool(8),
                           checkpoint_dir=tmp_path)
    sched.submit(SHARED4, qid="wide")
    sched.submit(SessionSpec("wrs", "local", world=2, substrate="vmap"),
                 qid="narrow")
    sched.tick()
    sched.save_all()
    with pytest.warns(UserWarning, match="wide"):
        resumed = EpochScheduler.resume(tmp_path, max_in_flight=4,
                                        pool=DevicePool(2))
    assert resumed.unresumed == ["wide"]
    resumed.drain()
    assert set(resumed.results) == {"narrow"}
    # the skipped checkpoint stays on disk, resumable on an adequate pool
    retry = EpochScheduler.resume(tmp_path, max_in_flight=4,
                                  pool=DevicePool(8))
    assert retry.unresumed == []
    retry.drain()
    assert "wide" in retry.results


# --------------------------------------------------------------- subprocess
# The real thing: disjoint shard_map submeshes need >1 device; force 8
# virtual host devices in a child (the flag must precede the first jax
# import and must not leak into this process).  When the parent already has
# ≥ 8 devices (the CI serve-placement job), run the same checks in-process.

_CHECKS_8DEV = """
import numpy as np
import jax
from repro.serve import (AdaptiveSession, DevicePool, DeviceTopology,
                         EpochScheduler, PressurePolicy, SessionSpec,
                         StepperCache)

SPEC = SessionSpec("reachability", "shared", world=4, substrate="shard_map")

def solo(spec):
    s = AdaptiveSession.create(spec).start().run()
    est, res = s.result()
    return np.asarray(est), res

def check_concurrent_disjoint():
    # reference: alone on the leading devices jax.devices()[:4]
    est_ref, res_ref = solo(SPEC)
    pool = DevicePool(DeviceTopology.from_host())
    sched = EpochScheduler(max_in_flight=4, pool=pool)
    sched.submit(SPEC, qid="a")
    sched.submit(SPEC, qid="b")      # same shape, same seed — same answer
    sched.tick()
    pa = sched._active["a"].spec.placement
    pb = sched._active["b"].spec.placement
    assert pa == (0, 1, 2, 3) and pb == (4, 5, 6, 7), (pa, pb)
    assert len(sched.cache) == 2, "same shape, disjoint submeshes must " \
        "compile distinct steppers"
    sched.drain()
    for qid in ("a", "b"):
        r = sched.results[qid]
        assert r.tau == res_ref.num, (qid, r.tau, res_ref.num)
        np.testing.assert_array_equal(r.estimate, est_ref)
        assert r.devices_leased == 4

def check_pressure_shrink_shard_map():
    import dataclasses
    est_ref, res_ref = solo(SPEC)
    pool = DevicePool(8)
    sched = EpochScheduler(max_in_flight=4, pool=pool,
                           pressure=PressurePolicy(min_world=2))
    sched.submit(SPEC, qid="A")
    # another 3-epoch W=4 session so the pool stays full when C arrives
    sched.submit(dataclasses.replace(SPEC, seed=1), qid="B")
    sched.submit(SessionSpec("wrs", "local", world=2,
                             substrate="shard_map"), qid="C")
    events = sched.drain()
    reshards = [e for ev in events for e in ev.resharded]
    assert ("A", 4, 2) in reshards, reshards
    rA = sched.results["A"]
    assert rA.spec.world == 2
    assert rA.spec.placement == (0, 1)      # kept the lease's leading half
    assert rA.tau == res_ref.num
    np.testing.assert_array_equal(rA.estimate, est_ref)
    assert sched.results["C"].placement_wait_ticks >= 1

def check_resume_releases_equivalent_devices(tmp):
    est_ref, res_ref = solo(SPEC)
    pool = DevicePool(8)
    sched = EpochScheduler(max_in_flight=4, pool=pool, checkpoint_dir=tmp)
    sched.submit(SPEC, qid="x")
    sched.submit(SPEC, qid="y")
    sched.tick()
    assert sched._active["y"].spec.placement == (4, 5, 6, 7)
    sched.save_all()
    # fresh pool with devices 4,5 already taken: y cannot get its recorded
    # submesh back and must be re-leased equivalent devices + rebound
    pool2 = DevicePool(8)
    blocker = pool2.lease(2, prefer=(4, 5))
    resumed = EpochScheduler.resume(tmp, max_in_flight=4, pool=pool2)
    resumed.drain()
    for qid in ("x", "y"):
        r = resumed.results[qid]
        assert r.tau == res_ref.num
        np.testing.assert_array_equal(r.estimate, est_ref)
    py = resumed.results["y"].spec.placement
    assert py is not None and set(py).isdisjoint(blocker.ids), py
    px = resumed.results["x"].spec.placement
    assert px == (0, 1, 2, 3), px     # recorded ids were free → re-leased
"""

_SCRIPT_8DEV = ("""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
assert len(jax.devices()) == 8
""" + _CHECKS_8DEV + """
check_concurrent_disjoint()
check_pressure_shrink_shard_map()
with tempfile.TemporaryDirectory() as tmp:
    check_resume_releases_equivalent_devices(tmp)
print("PLACEMENT_8DEV_OK")
""")


def _checks_namespace():
    ns = {}
    exec(compile(_CHECKS_8DEV, __file__ + "::_CHECKS_8DEV", "exec"), ns)
    return ns


needs_8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="disjoint W=4 submeshes need 8 devices (CI serve-placement job "
           "runs these in-process; elsewhere the subprocess below covers "
           "them)")


@needs_8
def test_concurrent_disjoint_sessions_bit_identical_to_solo():
    _checks_namespace()["check_concurrent_disjoint"]()


@needs_8
def test_pressure_shrink_shard_map_bit_identical():
    _checks_namespace()["check_pressure_shrink_shard_map"]()


@needs_8
def test_resume_re_leases_equivalent_devices(tmp_path):
    _checks_namespace()["check_resume_releases_equivalent_devices"](
        str(tmp_path))


@pytest.mark.skipif(
    len(jax.devices()) >= 8,
    reason="parent already runs the placement cells in-process (CI "
           "serve-placement job) — the subprocess would just repeat them")
def test_placement_under_forced_multidevice():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT_8DEV],
                       capture_output=True, text=True, env=env,
                       timeout=900, cwd=ROOT)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "PLACEMENT_8DEV_OK" in r.stdout

"""Device-topology pool: lease/release invariants, carving policy,
topology parsing, resize semantics, and the pressure-policy grammar.

The pool is pure bookkeeping over abstract device ids (JAX enters only via
``DeviceTopology.from_host`` / ``lease_devices``), so the invariants are
property-tested over random lease/release sequences without any devices:

* live leases are pairwise disjoint,
* ``free + in_use == capacity`` always, and lease → release round-trips
  restore capacity exactly,
* carving never exceeds (or leaves) the physical device set.
"""

import pytest

from repro.serve.placement import (DevicePool, DeviceTopology, PlacementWait,
                                   PressurePolicy)

from _hyp import given, settings, st


# ------------------------------------------------------------------ topology

def test_topology_parse_grammar():
    assert DeviceTopology.parse("8").groups == (tuple(range(8)),)
    assert DeviceTopology.parse("2x4").groups == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert DeviceTopology.parse("8").num_devices == 8
    with pytest.raises(ValueError):
        DeviceTopology.parse("0")
    with pytest.raises(ValueError):
        DeviceTopology.parse("0x4")


def test_topology_rejects_duplicates_and_empty():
    with pytest.raises(ValueError, match="duplicate"):
        DeviceTopology(groups=((0, 1), (1, 2)))
    with pytest.raises(ValueError, match="no devices"):
        DeviceTopology(groups=())


def test_topology_from_host_matches_jax():
    import jax
    topo = DeviceTopology.from_host()
    assert sorted(topo.ids) == sorted(d.id for d in jax.devices())


# ------------------------------------------------------------------- leasing

def test_lease_prefers_aligned_disjoint_blocks():
    pool = DevicePool(8)
    a, b = pool.lease(4), pool.lease(4)
    assert a.ids == (0, 1, 2, 3) and b.ids == (4, 5, 6, 7)
    assert pool.free == 0
    with pytest.raises(PlacementWait):
        pool.lease(1)
    pool.release(a)
    assert pool.free == 4 and pool.lease(4).ids == (0, 1, 2, 3)


def test_lease_stays_inside_one_group_when_possible():
    pool = DevicePool(DeviceTopology.parse("2x4"))
    a = pool.lease(2)            # group 0: [0, 1]
    b = pool.lease(4)            # group 0 has only [2, 3] left → group 1
    assert a.ids == (0, 1)
    assert b.ids == (4, 5, 6, 7)
    c = pool.lease(2)            # back to group 0's tail
    assert c.ids == (2, 3)


def test_lease_spans_groups_only_as_last_resort():
    pool = DevicePool(DeviceTopology.parse("2x2"))
    spanning = pool.lease(3)     # no group holds 3 — multi-host fallback
    assert spanning.ids == (0, 1, 2)


def test_lease_prefer_reclaims_exact_ids():
    pool = DevicePool(8)
    a = pool.lease(4)
    pool.release(a)
    again = pool.lease(4, prefer=(4, 5, 6, 7))
    assert again.ids == (4, 5, 6, 7)
    # preferred ids taken → fall back to policy placement of same width
    other = pool.lease(4, prefer=(4, 5, 6, 7))
    assert other.ids == (0, 1, 2, 3)


def test_lease_validation():
    pool = DevicePool(4)
    with pytest.raises(ValueError, match=">= 1"):
        pool.lease(0)
    with pytest.raises(ValueError, match="capacity"):
        pool.lease(5)
    lease = pool.lease(2)
    pool.release(lease)
    with pytest.raises(ValueError, match="not live"):
        pool.release(lease)


def test_release_of_stale_pre_resize_lease_does_not_double_free():
    """Releasing an outdated Lease object must free the pool's *current*
    record for that lid — not the stale ids — or two later leases could
    share a device."""
    pool = DevicePool(8)
    original = pool.lease(4)             # (0, 1, 2, 3)
    pool.resize(original, 2)             # live lease is now (0, 1)
    taken = pool.lease(2)                # takes the freed (2, 3)
    pool.release(original)               # stale handle: must free (0, 1)
    assert sorted(pool.free_ids()) == [0, 1, 4, 5, 6, 7]
    a, b = pool.lease(4), pool.lease(2)
    assert set(a.ids).isdisjoint(b.ids) and set(a.ids).isdisjoint(taken.ids)


def test_resize_shrink_keeps_leading_ids_and_grow_extends():
    pool = DevicePool(8)
    lease = pool.lease(4)
    small = pool.resize(lease, 2)
    assert small.ids == (0, 1) and small.lid == lease.lid
    assert pool.free_ids() == (2, 3, 4, 5, 6, 7)
    big = pool.resize(small, 4)
    assert big.ids == (0, 1, 2, 3)
    other = pool.lease(4)
    with pytest.raises(PlacementWait):
        pool.resize(big, 6)
    assert big.ids == (0, 1, 2, 3)   # failed grow left the lease intact
    pool.release(other)
    assert pool.resize(big, 4) is big


# ------------------------------------------------- property tests (tests/_hyp)

def _check_invariants(pool: DevicePool, capacity: int):
    live = pool.leases
    taken = [i for lease in live for i in lease.ids]
    assert len(set(taken)) == len(taken), "live leases must be disjoint"
    assert set(taken) | set(pool.free_ids()) == set(pool.topology.ids)
    assert pool.free + pool.in_use == capacity == pool.capacity
    assert set(taken) <= set(pool.topology.ids)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_pool_invariants_over_random_lease_release_sequences(data):
    capacity = data.draw(st.integers(min_value=1, max_value=16),
                         label="capacity")
    n_groups = data.draw(st.integers(min_value=1, max_value=3),
                         label="groups")
    per = max(1, capacity // n_groups)
    topo = DeviceTopology(groups=tuple(
        tuple(range(g * per, min((g + 1) * per, capacity)))
        for g in range(n_groups)
        if range(g * per, min((g + 1) * per, capacity))))
    pool = DevicePool(topo)
    capacity = pool.capacity
    live = []
    for _ in range(data.draw(st.integers(min_value=1, max_value=40),
                             label="ops")):
        do_lease = data.draw(st.booleans(), label="op") or not live
        if do_lease:
            width = data.draw(st.integers(min_value=1, max_value=capacity),
                              label="width")
            try:
                live.append(pool.lease(width))
            except PlacementWait:
                assert pool.free < width, \
                    "PlacementWait with enough free ids"
        else:
            idx = data.draw(st.integers(min_value=0,
                                        max_value=len(live) - 1),
                            label="victim")
            pool.release(live.pop(idx))
        _check_invariants(pool, capacity)
    for lease in live:
        pool.release(lease)
    assert pool.free == capacity, "release round-trip must restore capacity"
    assert pool.free_ids() == tuple(sorted(pool.topology.ids))


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_pool_resize_preserves_invariants(data):
    pool = DevicePool(data.draw(st.integers(min_value=2, max_value=12),
                                label="capacity"))
    capacity = pool.capacity
    lease = pool.lease(data.draw(
        st.integers(min_value=1, max_value=capacity), label="w0"))
    for _ in range(data.draw(st.integers(min_value=1, max_value=10),
                             label="resizes")):
        new_width = data.draw(st.integers(min_value=1, max_value=capacity),
                              label="w")
        try:
            lease = pool.resize(lease, new_width)
            assert lease.width == new_width
        except PlacementWait:
            assert new_width - lease.width > pool.free
        _check_invariants(pool, capacity)
    pool.release(lease)
    assert pool.free == capacity


# ------------------------------------------------------------ pressure policy

def test_pressure_policy_parse():
    assert PressurePolicy.parse("none") is None
    assert PressurePolicy.parse("") is None
    assert PressurePolicy.parse("shrink") == PressurePolicy(min_world=1,
                                                            regrow=False)
    assert PressurePolicy.parse("shrink-regrow:min=2") == \
        PressurePolicy(min_world=2, regrow=True)
    with pytest.raises(ValueError):
        PressurePolicy.parse("grow")
    with pytest.raises(ValueError):
        PressurePolicy.parse("shrink:max=3")

"""Graph substrate: BFS/σ counting vs numpy, CC, path-sampling distribution."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import (bfs_sssp, connected_components, eccentricity,
                          erdos_renyi, from_edges, grid2d, sample_path)
from repro.graphs.bfs import INF


def np_bfs(g, s):
    n = g.n
    indptr = np.asarray(g.indptr)
    idx = np.asarray(g.indices_padded)[: g.m_arcs]
    dist = np.full(n, -1)
    sigma = np.zeros(n)
    dist[s] = 0
    sigma[s] = 1
    from collections import deque
    q = deque([s])
    while q:
        v = q.popleft()
        for w in idx[indptr[v]:indptr[v + 1]]:
            if dist[w] < 0:
                dist[w] = dist[v] + 1
                q.append(w)
            if dist[w] == dist[v] + 1:
                sigma[w] += sigma[v]
    return dist, sigma


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bfs_matches_numpy(seed):
    g = erdos_renyi(80, 200, seed=seed)
    dist, sigma = bfs_sssp(g, jnp.int32(5), None, max_levels=g.n,
                           early_exit=False)
    nd, ns = np_bfs(g, 5)
    dj = np.asarray(dist)
    dj = np.where(dj == int(INF), -1, dj)
    np.testing.assert_array_equal(dj, nd)
    np.testing.assert_allclose(np.asarray(sigma), ns, rtol=1e-5)


def test_grid_diameter():
    g = grid2d(5, 7)
    ecc = int(eccentricity(g, jnp.int32(0), max_levels=g.n))
    assert ecc == 4 + 6  # manhattan corner-to-corner


def test_connected_components_two_islands():
    edges = np.array([[0, 1], [1, 2], [3, 4]])
    g = from_edges(5, edges)
    comps = np.asarray(connected_components(g))
    assert comps[0] == comps[1] == comps[2]
    assert comps[3] == comps[4]
    assert comps[0] != comps[3]


def test_sample_path_distribution_uniform():
    """Diamond graph: two shortest 0→3 paths; sampling must be ~50/50."""
    #   0 - 1 - 3
    #    \- 2 -/
    g = from_edges(4, np.array([[0, 1], [0, 2], [1, 3], [2, 3]]))
    dist, sigma = bfs_sssp(g, jnp.int32(0), jnp.int32(3), max_levels=5,
                           early_exit=False)
    keys = jax.random.split(jax.random.key(0), 400)
    masks = jax.vmap(lambda k: sample_path(
        g, k, jnp.int32(0), jnp.int32(3), dist, sigma, max_len=4))(keys)
    m = np.asarray(masks)
    # internal vertices only: 1 xor 2, never 0/3
    assert m[:, 0].sum() == 0 and m[:, 3].sum() == 0
    assert np.all(m[:, 1] ^ m[:, 2])
    frac = m[:, 1].mean()
    assert 0.4 < frac < 0.6, f"path sampling biased: {frac}"


def test_sample_path_weighted_by_sigma():
    """σ-weighted predecessor choice: vertex with 2 incoming shortest paths
    is picked 2/3 of the time."""
    # 0→{1,2}→3→... path counting: build 0-1,0-2,1-3,2-3,1-4,4-3? Use:
    # 0 connects to 1 and 2; 1 and 2 connect to 3; plus 0-5, 5-1 gives 1 an
    # extra shortest path? Keep the diamond + pentagon mix simple:
    g = from_edges(6, np.array([
        [0, 1], [0, 2], [1, 3], [2, 3], [3, 4], [0, 5], [5, 4]]))
    dist, sigma = bfs_sssp(g, jnp.int32(0), jnp.int32(4), max_levels=6,
                           early_exit=False)
    # σ(4): via 3 (2 paths) + via 5 (1 path) at dist 3? dist(4)=2 via 5,
    # dist via 3 is 3 — so only the 0-5-4 path is shortest; check that:
    assert int(dist[4]) == 2
    keys = jax.random.split(jax.random.key(1), 100)
    masks = jax.vmap(lambda k: sample_path(
        g, k, jnp.int32(0), jnp.int32(4), dist, sigma, max_len=4))(keys)
    m = np.asarray(masks)
    assert np.all(m[:, 5]), "unique shortest path must go through 5"


def test_disconnected_pair_contributes_zero():
    g = from_edges(4, np.array([[0, 1], [2, 3]]))
    dist, sigma = bfs_sssp(g, jnp.int32(0), jnp.int32(3), max_levels=5,
                           early_exit=False)
    mask = sample_path(g, jax.random.key(0), jnp.int32(0), jnp.int32(3),
                       dist, sigma, max_len=4)
    assert not np.asarray(mask).any()

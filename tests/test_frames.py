"""Frame semantics + the paper's associativity requirement (property-based)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.frames import (StateFrame, accumulate,
                               axis_collectives, combine, shard_frame_pad,
                               shard_groups, zeros_like_frame)


def frame_of(arr):
    return StateFrame(num=jnp.int32(arr.shape[0] if arr.ndim else 1),
                      data=jnp.asarray(arr))


def test_zeros_like_frame():
    f = zeros_like_frame(jnp.ones((5,), jnp.int32))
    assert int(f.num) == 0
    np.testing.assert_array_equal(np.asarray(f.data), np.zeros(5))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-100, 100), min_size=1, max_size=8),
       st.lists(st.integers(-100, 100), min_size=1, max_size=8),
       st.lists(st.integers(-100, 100), min_size=1, max_size=8))
def test_combine_associative(a, b, c):
    n = min(len(a), len(b), len(c))
    fa, fb, fc = (StateFrame(num=jnp.int32(1),
                             data=jnp.asarray(x[:n], jnp.int32))
                  for x in (a, b, c))
    left = combine(combine(fa, fb), fc)
    right = combine(fa, combine(fb, fc))
    assert int(left.num) == int(right.num) == 3
    np.testing.assert_array_equal(np.asarray(left.data),
                                  np.asarray(right.data))


def test_accumulate_matches_loop():
    rng = np.random.default_rng(0)
    stack = rng.integers(0, 50, size=(7, 13)).astype(np.int32)
    frames = StateFrame(num=jnp.ones((7,), jnp.int32),
                        data=jnp.asarray(stack))
    acc = accumulate(frames)
    assert int(acc.num) == 7
    np.testing.assert_array_equal(np.asarray(acc.data), stack.sum(0))


def test_shard_frame_pad():
    assert shard_frame_pad(10, 4) == 12
    assert shard_frame_pad(8, 4) == 8
    assert shard_frame_pad(1, 3) == 3


# ----------------------------------------------------- frame monoid (∘, 0)
# Algorithm 1's correctness rests on (frames, ∘) being a commutative monoid
# with zeros_like_frame as identity.  Property-checked over random *pytrees*
# (dict/tuple nesting, mixed dtypes) — not just flat vectors.


def _tree_frame(rng, n, m, dtype=np.int32):
    """A frame whose data is a nested pytree with integer leaves."""
    return StateFrame(
        num=jnp.int32(int(rng.integers(0, 10))),
        data={"v": jnp.asarray(rng.integers(-50, 50, size=(n,)), dtype),
              "nest": (jnp.asarray(rng.integers(-50, 50, size=(m, 2)),
                                   dtype),
                       jnp.asarray(rng.integers(-50, 50, size=()), dtype))})


def _frames_equal(a: StateFrame, b: StateFrame) -> bool:
    if int(a.num) != int(b.num):
        return False
    la, lb = jax.tree.leaves(a.data), jax.tree.leaves(b.data)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_combine_associative_commutative_over_pytrees(n, m, seed):
    rng = np.random.default_rng(seed)
    fa, fb, fc = (_tree_frame(rng, n, m) for _ in range(3))
    assert _frames_equal(combine(combine(fa, fb), fc),
                         combine(fa, combine(fb, fc)))
    assert _frames_equal(combine(fa, fb), combine(fb, fa))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_zeros_like_frame_is_identity(n, m, seed):
    rng = np.random.default_rng(seed)
    f = _tree_frame(rng, n, m)
    zero = zeros_like_frame(f.data)
    assert int(zero.num) == 0
    assert _frames_equal(combine(f, zero), f)
    assert _frames_equal(combine(zero, f), f)
    # identity preserves dtypes (zeros_like must not promote)
    for za, xa in zip(jax.tree.leaves(zero.data), jax.tree.leaves(f.data)):
        assert za.dtype == xa.dtype


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 10_000), st.integers(1, 64))
def test_shard_frame_pad_divisible_and_minimal(n, world):
    pad = shard_frame_pad(n, world)
    assert pad % world == 0          # reduce-scatter needs W | pad
    assert pad >= n                  # never truncates
    assert pad - n < world           # minimal: less than one extra shard row
    if n % world == 0:
        assert pad == n              # already aligned → untouched


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 6), st.integers(0, 4))
def test_shard_groups_partition_world(f_exp, g_exp):
    F, groups = 2 ** f_exp, 2 ** g_exp
    world = F * groups
    within, across = shard_groups(world, F)
    # 'within' partitions the workers into world/F groups of F …
    assert sorted(w for g in within for w in g) == list(range(world))
    assert all(len(g) == F for g in within)
    # … 'across' into F groups of world/F, transposed
    assert sorted(w for g in across for w in g) == list(range(world))
    assert all(len(g) == world // F for g in across)
    for i in range(F):
        assert across[i] == [g[i] for g in within]


def test_axis_collectives_vmap_psum_and_scatter():
    colls = axis_collectives("w", 4)

    def worker(x):
        f = StateFrame(num=jnp.int32(1), data=x)
        red = colls.reduce_frames(f)
        sc = colls.scatter_frames(f)
        gathered = colls.all_frames(f)
        return red, sc, gathered

    xs = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    red, sc, gathered = jax.vmap(worker, axis_name="w")(xs)
    # reduce: every worker sees the full sum
    np.testing.assert_allclose(np.asarray(red.data),
                               np.tile(xs.sum(0), (4, 1)))
    assert np.all(np.asarray(red.num) == 4)
    # scatter: worker i holds shard i of the sum
    np.testing.assert_allclose(np.asarray(sc.data).reshape(-1),
                               np.asarray(xs.sum(0)))
    # gather: every worker sees all deltas
    assert np.asarray(gathered.data).shape == (4, 4, 4)


def test_axis_collectives_f_less_than_w_reference_layout():
    """vmap reference form of the F<W SHARED reduction: worker g·F+i ends
    up with shard i of the GLOBAL sum (groups hold redundant copies)."""
    W, F = 4, 2
    colls = axis_collectives("w", W, frame_shards=F)

    def worker(x):
        return colls.scatter_frames(StateFrame(num=jnp.int32(1), data=x))

    xs = jnp.arange(W * 8, dtype=jnp.int32).reshape(W, 8)
    sc = jax.vmap(worker, axis_name="w")(xs)
    total = np.asarray(xs.sum(0))
    out = np.asarray(sc.data)
    assert out.shape == (W, 8 // F)
    assert np.all(np.asarray(sc.num) == W)
    for w in range(W):
        i = w % F
        np.testing.assert_array_equal(out[w], total[i * 4:(i + 1) * 4])

"""Frame semantics + the paper's associativity requirement (property-based)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.frames import (StateFrame, accumulate,
                               axis_collectives, combine, shard_frame_pad,
                               zeros_like_frame)


def frame_of(arr):
    return StateFrame(num=jnp.int32(arr.shape[0] if arr.ndim else 1),
                      data=jnp.asarray(arr))


def test_zeros_like_frame():
    f = zeros_like_frame(jnp.ones((5,), jnp.int32))
    assert int(f.num) == 0
    np.testing.assert_array_equal(np.asarray(f.data), np.zeros(5))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-100, 100), min_size=1, max_size=8),
       st.lists(st.integers(-100, 100), min_size=1, max_size=8),
       st.lists(st.integers(-100, 100), min_size=1, max_size=8))
def test_combine_associative(a, b, c):
    n = min(len(a), len(b), len(c))
    fa, fb, fc = (StateFrame(num=jnp.int32(1),
                             data=jnp.asarray(x[:n], jnp.int32))
                  for x in (a, b, c))
    left = combine(combine(fa, fb), fc)
    right = combine(fa, combine(fb, fc))
    assert int(left.num) == int(right.num) == 3
    np.testing.assert_array_equal(np.asarray(left.data),
                                  np.asarray(right.data))


def test_accumulate_matches_loop():
    rng = np.random.default_rng(0)
    stack = rng.integers(0, 50, size=(7, 13)).astype(np.int32)
    frames = StateFrame(num=jnp.ones((7,), jnp.int32),
                        data=jnp.asarray(stack))
    acc = accumulate(frames)
    assert int(acc.num) == 7
    np.testing.assert_array_equal(np.asarray(acc.data), stack.sum(0))


def test_shard_frame_pad():
    assert shard_frame_pad(10, 4) == 12
    assert shard_frame_pad(8, 4) == 8
    assert shard_frame_pad(1, 3) == 3


def test_axis_collectives_vmap_psum_and_scatter():
    colls = axis_collectives("w", 4)

    def worker(x):
        f = StateFrame(num=jnp.int32(1), data=x)
        red = colls.reduce_frames(f)
        sc = colls.scatter_frames(f)
        gathered = colls.all_frames(f)
        return red, sc, gathered

    xs = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    red, sc, gathered = jax.vmap(worker, axis_name="w")(xs)
    # reduce: every worker sees the full sum
    np.testing.assert_allclose(np.asarray(red.data),
                               np.tile(xs.sum(0), (4, 1)))
    assert np.all(np.asarray(red.num) == 4)
    # scatter: worker i holds shard i of the sum
    np.testing.assert_allclose(np.asarray(sc.data).reshape(-1),
                               np.asarray(xs.sum(0)))
    # gather: every worker sees all deltas
    assert np.asarray(gathered.data).shape == (4, 4, 4)

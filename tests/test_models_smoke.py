"""Per-arch smoke tests (deliverable f): one reduced-config forward/train
step + prefill + decode on CPU asserting shapes and no NaNs."""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import Model
from repro.optim.adamw import adamw_init

ARCH_MODULES = [
    "falcon_mamba_7b", "seamless_m4t_large_v2", "mixtral_8x22b",
    "qwen3_moe_235b_a22b", "mistral_large_123b", "internlm2_20b",
    "h2o_danube_3_4b", "smollm_360m", "internvl2_76b", "recurrentgemma_2b",
]
B, S = 2, 32


def build_batch(cfg):
    batch = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
             "labels": jnp.ones((B, S), jnp.int32) * 3}
    if cfg.family == "vlm":
        batch = {"tokens": jnp.ones((B, S - cfg.n_patches), jnp.int32),
                 "labels": jnp.ones((B, S - cfg.n_patches), jnp.int32),
                 "patches": jnp.ones((B, cfg.n_patches, cfg.d_model),
                                     jnp.bfloat16)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, S // cfg.frame_ratio, cfg.d_model),
                                   jnp.bfloat16)
    return batch


@pytest.mark.parametrize("mod_name", ARCH_MODULES)
def test_arch_smoke(mod_name):
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.reduced()
    model = Model(cfg, None)
    params = model.init(jax.random.key(0))
    batch = build_batch(cfg)

    # train step: finite loss, param shapes preserved
    ts = jax.jit(make_train_step(model))
    p2, o2, metrics = ts(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    same = jax.tree.map(lambda a, b: a.shape == b.shape, params, p2)
    assert all(jax.tree.leaves(same))
    # loss actually decreases after a step on the same batch
    l2 = float(model.train_loss(p2, batch))
    assert l2 < float(metrics["loss"]) + 1e-3

    # prefill: last-token logits, no NaN
    pf = jax.jit(make_prefill_step(model))
    pbatch = {k: v for k, v in batch.items() if k != "labels"}
    logits = pf(params, pbatch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # decode: one token, cache shapes stable
    cache = model.init_cache(B, 64)
    sv = jax.jit(make_serve_step(model))
    c2, nxt = sv(params, cache,
                 {"tokens": jnp.ones((B,), jnp.int32),
                  "pos": jnp.full((B,), 3, jnp.int32)})
    assert nxt.shape == (B,)
    assert np.all(np.asarray(nxt) >= 0)
    same_c = jax.tree.map(lambda a, b: a.shape == b.shape, cache, c2)
    assert all(jax.tree.leaves(same_c))


def test_full_configs_have_exact_dims():
    """The registered full configs carry the published dimensions."""
    from repro.models import all_configs
    cfgs = all_configs()
    assert len(cfgs) == 10
    c = cfgs["mistral-large-123b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (88, 12288, 96, 8, 28672, 32768)
    c = cfgs["qwen3-moe-235b-a22b"]
    assert (c.n_experts, c.top_k, c.vocab) == (128, 8, 151936)
    c = cfgs["falcon-mamba-7b"]
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == \
        (64, 4096, 16, 65024)
    c = cfgs["recurrentgemma-2b"]
    assert (c.n_layers, c.d_model, c.local_window) == (26, 2560, 2048)
    assert cfgs["smollm-360m"].n_heads == 15
    assert cfgs["seamless-m4t-large-v2"].vocab == 256206


def test_param_counts_plausible():
    """Closed-form param counts land in the right ballpark per arch."""
    from repro.models import all_configs
    expect = {
        "falcon-mamba-7b": (6e9, 9e9),
        "mixtral-8x22b": (120e9, 160e9),
        "qwen3-moe-235b-a22b": (180e9, 280e9),
        "mistral-large-123b": (110e9, 135e9),
        "internlm2-20b": (17e9, 23e9),
        "h2o-danube-3-4b": (3e9, 5e9),
        "smollm-360m": (0.3e9, 0.5e9),
        "internvl2-76b": (60e9, 80e9),
        "recurrentgemma-2b": (2e9, 4e9),
        "seamless-m4t-large-v2": (1e9, 3e9),
    }
    for name, (lo, hi) in expect.items():
        n = all_configs()[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_gradient_health_at_depth():
    """Regression: gradients must not grow exponentially with depth.

    Guards two past bugs: (a) 3-D projections inferring fan-in from
    shape[-2] (8× oversized wq/wk init → saturated attention → 1e6 gnorms
    at L=12), (b) missing 1/√(2L) residual-output scaling."""
    from repro.models import ModelConfig
    from repro.data import TokenStream

    b = TokenStream(vocab=1000, seq_len=32, batch=4, seed=0).batch_at(
        jnp.int32(0))
    norms = {}
    for L in (1, 8):
        cfg = ModelConfig(name=f"gh{L}", family="dense", n_layers=L,
                          d_model=128, n_heads=4, n_kv=2, d_ff=256,
                          vocab=1000, remat="none", attn_chunk=4096)
        model = Model(cfg, None)
        params = model.init(jax.random.key(0))
        _, g = jax.value_and_grad(model.train_loss)(params, b)
        norms[L] = float(jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(g))))
    assert norms[8] < 40 * norms[1], norms   # sublinear-ish, not 2^L
    assert norms[8] < 1e3, norms

"""Epoch-granular scheduler: admission policy, epoch-boundary retirement,
no head-of-line blocking, result fidelity vs solo sessions, stepper-cache
sharing, and preemption (checkpoint-all → resume) mid-stream."""

import numpy as np
import pytest

from repro.serve import AdaptiveSession, EpochScheduler, SessionSpec

# small, fast specs (vmap W=2); wrs retires in ~2-3 epochs, reachability
# and triangles run longer — enough spread to exercise continuous batching.
WRS = SessionSpec("wrs", "local", world=2, seed=0)
TRI = SessionSpec("triangles", "local", world=2, seed=1)
REACH = SessionSpec("reachability", "local", world=2, seed=2)


def test_admission_policy_bounds_in_flight():
    sched = EpochScheduler(max_in_flight=2)
    for i, spec in enumerate([WRS, TRI, REACH, WRS]):
        sched.submit(spec, qid=f"q{i}")
    seen_in_flight = []
    while not sched.idle:
        sched.tick()
        seen_in_flight.append(sched.in_flight)
    assert max(seen_in_flight) <= 2
    assert len(sched.results) == 4
    # the overflow queries waited at least one tick
    waits = {qid: r.wait_ticks for qid, r in sched.results.items()}
    assert waits["q0"] == 0 and waits["q1"] == 0
    assert waits["q2"] >= 1 and waits["q3"] >= 1


def test_results_bit_identical_to_solo_sessions():
    """Interleaving queries in one pool must not change any query's
    trajectory: each result equals the solo AdaptiveSession run."""
    sched = EpochScheduler(max_in_flight=2)
    specs = {"a": WRS, "b": TRI, "c": REACH}
    for qid, spec in specs.items():
        sched.submit(spec, qid=qid)
    sched.drain()
    for qid, spec in specs.items():
        solo = AdaptiveSession.create(spec).start().run()
        est, res = solo.result()
        got = sched.results[qid]
        assert got.tau == res.num
        assert got.epochs == res.epochs
        assert got.stopped
        np.testing.assert_array_equal(got.estimate, np.asarray(est))


def test_no_head_of_line_blocking():
    """A short query admitted alongside a long one retires first; a query
    queued behind it is admitted the very next tick — the long query never
    monopolizes the pool."""
    sched = EpochScheduler(max_in_flight=2)
    sched.submit(REACH, qid="long")     # ~4 epochs
    sched.submit(WRS, qid="short")      # ~2 epochs
    sched.submit(TRI, qid="queued")
    events = sched.drain()
    retire_tick = {qid: ev.tick for ev in events for qid in ev.retired}
    admit_tick = {qid: ev.tick for ev in events for qid in ev.admitted}
    assert retire_tick["short"] < retire_tick["long"]
    assert admit_tick["queued"] == retire_tick["short"] + 1
    assert len(sched.results) == 3


def test_tau_accounting_per_query():
    sched = EpochScheduler(max_in_flight=3)
    sched.submit(WRS, qid="w")
    sched.drain()
    r = sched.results["w"]
    built = AdaptiveSession.create(WRS).built
    unit = built.samples_per_round * built.rounds_per_epoch * WRS.world
    assert r.tau > 0 and r.tau % unit == 0
    assert r.retired_tick >= r.admitted_tick >= r.submitted_tick
    assert r.wall_s > 0


def test_stepper_cache_shared_across_seeds():
    """Differently-seeded queries of the same shape share one compiled
    stepper (seed is a traced scalar, not a compile-time constant)."""
    sched = EpochScheduler(max_in_flight=4)
    import dataclasses
    for seed in range(3):
        sched.submit(dataclasses.replace(WRS, seed=seed))
    sched.drain()
    assert len(sched.results) == 3
    assert len(sched.cache) == 1
    taus = {r.tau for r in sched.results.values()}
    assert len(taus) >= 1          # seeds may or may not change tau; all ran


def test_checkpoint_all_and_resume(tmp_path):
    """Preempt a half-drained pool, resume from disk, drain: the union of
    results matches an uninterrupted pool bit-for-bit."""
    ref = EpochScheduler(max_in_flight=2)
    for qid, spec in [("a", WRS), ("b", REACH), ("c", TRI)]:
        ref.submit(spec, qid=qid)
    ref.drain()

    sched = EpochScheduler(max_in_flight=2, checkpoint_dir=tmp_path)
    for qid, spec in [("a", WRS), ("b", REACH), ("c", TRI)]:
        sched.submit(spec, qid=qid)
    sched.tick()                   # some progress, nothing drained
    sched.save_all()
    done_early = dict(sched.results)

    resumed = EpochScheduler.resume(tmp_path, max_in_flight=2)
    # queries never admitted before the preemption are resubmitted fresh
    restored = {qid for qid, *_ in resumed._queue}
    for qid, spec in [("a", WRS), ("b", REACH), ("c", TRI)]:
        if qid not in restored and qid not in done_early:
            resumed.submit(spec, qid=qid)
    resumed.drain()

    merged = {**done_early, **resumed.results}
    assert set(merged) == {"a", "b", "c"}
    for qid in ("a", "b", "c"):
        assert merged[qid].tau == ref.results[qid].tau
        assert merged[qid].epochs == ref.results[qid].epochs
        np.testing.assert_array_equal(merged[qid].estimate,
                                      ref.results[qid].estimate)


def test_resume_recovers_unretired_queries_without_session_checkpoints(
        tmp_path):
    """Hard preemption (no save_all, checkpoint_every=0): queued queries
    AND admitted-but-never-checkpointed queries survive via queue.json —
    resubmitted fresh rather than silently dropped."""
    sched = EpochScheduler(max_in_flight=1, checkpoint_dir=tmp_path)
    for qid, spec in [("a", WRS), ("b", TRI), ("c", REACH)]:
        sched.submit(spec, qid=qid)
    sched.tick()                   # admits only "a"; no session checkpoints
    assert (tmp_path / "queue.json").exists()
    # process dies here — rebuild purely from disk
    resumed = EpochScheduler.resume(tmp_path, max_in_flight=2)
    resumed.drain()
    assert set(resumed.results) == {"a", "b", "c"}
    ref = EpochScheduler(max_in_flight=2)
    ref.submit(WRS, qid="a")
    ref.drain()
    assert resumed.results["a"].tau == ref.results["a"].tau


def test_resume_auto_ids_skip_restored_ids(tmp_path):
    """After a resume, auto-generated query ids never collide with
    restored ones."""
    sched = EpochScheduler(max_in_flight=1, checkpoint_dir=tmp_path)
    sched.submit(WRS)              # auto id q000-wrs
    sched.tick()
    sched.save_all()
    resumed = EpochScheduler.resume(tmp_path, max_in_flight=1)
    qid2 = resumed.submit(WRS)     # counter restarts at 0 — must not clash
    assert qid2 != "q000-wrs"
    resumed.drain()
    assert {"q000-wrs", qid2} <= set(resumed.results)


def test_scheduler_validation():
    with pytest.raises(ValueError):
        EpochScheduler(max_in_flight=0)
    sched = EpochScheduler()
    sched.submit(WRS, qid="dup")
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(WRS, qid="dup")


def test_substrate_override_applies_to_submitted_specs():
    sched = EpochScheduler(max_in_flight=1, substrate="vmap")
    qid = sched.submit(SessionSpec("wrs", "local", world=2))
    sched.drain()
    assert sched.results[qid].spec.substrate == "vmap"

"""Sharding policy (divisibility fallback) + HLO collective parser +
roofline math."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import collective_bytes, parse_shape_bytes
from repro.core.compat import shard_map
from repro.analysis.roofline import (combine_layer_diff, model_flops,
                                     roofline_terms)
from repro.models import SHAPES, get_config
from repro.models.layers import ShardingRules


def rules_16():
    return ShardingRules(
        rules={"vocab": ("model",), "heads": ("model",), "ffn": ("model",),
               "embed": ("data",), "batch": ("data",)},
        mesh_shape={"data": 16, "model": 16})


def test_divisibility_fallback():
    r = rules_16()
    # 15 heads don't divide 16 → replicated (3-D head-major params make the
    # check hit the head COUNT, not the fused H·hd dim); 2560 ffn → sharded
    spec = r.spec_for_shape((960, 15, 64), ("embed", "heads", None))
    assert spec == P("data", None, None)
    spec = r.spec_for_shape((960, 2560), ("embed", "ffn"))
    assert spec == P("data", "model")
    # divisible head count shards normally
    spec = r.spec_for_shape((6144, 48, 128), ("embed", "heads", None))
    assert spec == P("data", "model", None)


def test_axis_used_once():
    r = ShardingRules(rules={"a": ("model",), "b": ("model",)},
                      mesh_shape={"model": 4})
    spec = r.spec_for_shape((8, 8), ("a", "b"))
    # 'model' must not be assigned to two dims of one tensor
    assert spec in (P("model", None), P(None, "model"))


def test_multi_axis_dim():
    r = ShardingRules(rules={"embed": ("pod", "data")},
                      mesh_shape={"pod": 2, "data": 16})
    assert r.spec_for_shape((64,), ("embed",)) == P(("pod", "data"))
    # 33 not divisible by 2 → fully replicated
    assert r.spec_for_shape((33,), ("embed",)) == P(None)


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[128]") == 512
    assert parse_shape_bytes("bf16[2,3]{1,0}") == 12
    assert parse_shape_bytes("pred[] s8[10]") == 11  # 1-byte scalar + 10
    assert parse_shape_bytes("u32[4,4]") == 64


def test_collective_bytes_on_real_hlo():
    mesh = jax.make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P()))
    txt = g.lower(jnp.ones((8, 128), jnp.float32)).compile().as_text()
    out = collective_bytes(txt)
    # single-device psum may be optimized away; at minimum the parser
    # must not crash and must return the dict shape
    assert "total" in out and "count" in out


def test_collective_bytes_synthetic():
    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag.s = (f32[256]{0}, f32[1024]{0}) all-gather-start(f32[256]{0} %y)
  %ag.d = f32[1024]{0} all-gather-done((f32[256]{0}, f32[1024]{0}) %ag.s)
  %cp = bf16[64,64]{1,0} collective-permute(bf16[64,64]{1,0} %z)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 4096
    assert out["all-gather"] == 1024          # operand of -start
    assert out["collective-permute"] == 8192
    assert out["count"] == 3                  # -done skipped


def test_roofline_terms_dominance():
    t = roofline_terms(flops_per_dev=197e12, bytes_per_dev=1e9,
                       coll_bytes_per_dev=1e9, chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.dominant == "compute"
    t = roofline_terms(flops_per_dev=1e12, bytes_per_dev=819e9 * 2,
                       coll_bytes_per_dev=1e9, chips=256)
    assert t.dominant == "memory"


def test_layer_differencing():
    base = {"flops": 100.0, "bytes": 10.0}
    two = {"flops": 160.0, "bytes": 14.0}
    out = combine_layer_diff(base, two, 11)
    assert out["flops"] == pytest.approx(100 + 60 * 10)
    assert out["bytes"] == pytest.approx(10 + 4 * 10)


def test_model_flops_forms():
    cfg = get_config("mistral-large-123b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert tr == pytest.approx(6 * n * 4096 * 256, rel=1e-6)
    assert pf == pytest.approx(2 * n * 32768 * 32, rel=1e-6)
    assert dc == pytest.approx(2 * n * 128, rel=1e-6)
    # MoE: active < total
    moe = get_config("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.25 * moe.param_count()

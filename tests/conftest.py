import sys
from pathlib import Path

# NB: no XLA_FLAGS here — tests must see the real single CPU device
# (the dry-run sets its own 512-device flag in its subprocess).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

"""BENCH_*.json perf-artifact pipeline: writer/validator round-trip, schema
violations, speedup attachment, and the perf summary."""

import json
import sys
from pathlib import Path

import pytest

# benchmarks/ is a sibling of tests/ — importable from the repo root
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.artifact import (SCHEMA_VERSION, _cli, attach_speedups,  # noqa: E402
                                 diff_bench, doc_kind, load_bench,
                                 validate_bench, write_bench)
from benchmarks.perf_summary import summarize  # noqa: E402


def _rows():
    return [
        {"workload": "wrs", "strategy": "barrier", "world": 1,
         "us_per_call": 100.0, "tau": 1024},
        {"workload": "wrs", "strategy": "local", "world": 1,
         "us_per_call": 50.0, "tau": 1024},
        {"workload": "diameter", "strategy": "indexed", "world": 4,
         "us_per_call": 75.0, "tau": 16},
    ]


def test_attach_speedups():
    rows = attach_speedups(_rows())
    by = {(r["workload"], r["strategy"]): r for r in rows}
    assert by[("wrs", "barrier")]["speedup_vs_barrier"] == 1.0
    assert by[("wrs", "local")]["speedup_vs_barrier"] == 2.0
    # no BARRIER baseline for that (workload, world) cell → null
    assert by[("diameter", "indexed")]["speedup_vs_barrier"] is None


def test_write_load_roundtrip(tmp_path):
    path = write_bench("instances", attach_speedups(_rows()),
                       out_dir=tmp_path, scale="conformance")
    assert path.name == "BENCH_instances.json"
    doc = load_bench(path)
    assert doc["schema_version"] == SCHEMA_VERSION
    assert doc["suite"] == "instances" and len(doc["rows"]) == 3
    assert {"jax_version", "platform", "created_unix"} <= set(doc)
    assert not validate_bench(doc)


def test_writer_refuses_invalid_rows(tmp_path):
    bad = [{"workload": "wrs", "strategy": "warp", "world": 1,
            "us_per_call": 1.0, "tau": 1, "speedup_vs_barrier": None}]
    with pytest.raises(ValueError, match="strategy"):
        write_bench("instances", bad, out_dir=tmp_path)


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.pop("jax_version"), "jax_version"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d.update(scale="huge"), "scale"),
    (lambda d: d.update(rows=[]), "empty"),
    (lambda d: d["rows"][0].pop("tau"), "tau"),
    (lambda d: d["rows"][0].update(us_per_call=0.0), "us_per_call"),
    (lambda d: d["rows"][0].update(world=0), "world"),
    (lambda d: d["rows"][1].update(speedup_vs_barrier=None), "null"),
    (lambda d: d["rows"][2].update(speedup_vs_barrier=3.0), "without"),
])
def test_validator_catches(tmp_path, mutate, needle):
    path = write_bench("instances", attach_speedups(_rows()),
                       out_dir=tmp_path)
    doc = json.loads(path.read_text())
    mutate(doc)
    errs = validate_bench(doc)
    assert errs and any(needle in e for e in errs), errs


def test_perf_summary_output(tmp_path):
    path = write_bench("instances", attach_speedups(_rows()),
                       out_dir=tmp_path)
    out = summarize(load_bench(path))
    assert "suite=instances" in out
    assert "best[wrs]: local W=1 at 2.00x" in out


# ------------------------------------------------------------ artifact diff

def _doc(rows):
    return {"schema_version": SCHEMA_VERSION, "suite": "instances",
            "jax_version": "0.4.37", "platform": "cpu",
            "created_unix": 0.0, "scale": "conformance",
            "rows": attach_speedups([dict(r) for r in rows])}


def test_diff_identical_passes():
    rep = diff_bench(_doc(_rows()), _doc(_rows()))
    assert rep["ok"]
    assert not rep["regressions"] and not rep["missing"]
    assert rep["unchanged"] == 3


def test_diff_within_band_passes():
    new = _rows()
    new[0]["us_per_call"] *= 1.10          # +10% < rtol=0.25 band
    new[1]["us_per_call"] += 20.0          # +40% but < min_us floor
    rep = diff_bench(_doc(_rows()), _doc(new), rtol=0.25, min_us=50.0)
    assert rep["ok"], rep["lines"]
    assert rep["unchanged"] == 3


def test_diff_flags_regression_beyond_band():
    new = _rows()
    new[0]["us_per_call"] = 300.0          # 3.0x and +200us: out of band
    rep = diff_bench(_doc(_rows()), _doc(new), rtol=0.25, min_us=50.0)
    assert not rep["ok"]
    assert rep["regressions"] == ["wrs/barrier/W=1"]
    assert any("REGRESS" in ln and "3.00x" in ln for ln in rep["lines"])


def test_diff_flags_improvement_without_failing():
    new = _rows()
    new[0]["us_per_call"] = 10.0
    rep = diff_bench(_doc(_rows()), _doc(new))
    assert rep["ok"]
    assert rep["improvements"] == ["wrs/barrier/W=1"]


def test_diff_missing_key_fails_added_does_not():
    old, new = _rows(), _rows()
    dropped = new.pop(2)                   # diameter row vanishes
    new.append({"workload": "kadabra", "strategy": "local", "world": 8,
                "us_per_call": 42.0, "tau": 64})
    rep = diff_bench(_doc(old), _doc(new))
    assert not rep["ok"]
    assert rep["missing"] == [f"{dropped['workload']}/indexed/W=4"]
    assert rep["added"] == ["kadabra/local/W=8"]
    # the added row alone must not fail the gate
    rep2 = diff_bench(_doc(old), _doc(old + [new[-1]]))
    assert rep2["ok"] and rep2["added"] == ["kadabra/local/W=8"]


def test_diff_tau_change_always_fails():
    new = _rows()
    new[1]["tau"] = 2048                   # same timing, different semantics
    rep = diff_bench(_doc(_rows()), _doc(new))
    assert not rep["ok"]
    assert rep["tau_changes"] == ["wrs/local/W=1"]


# ---------------------------------------------------------- kind = "serve"

def _serve_rows():
    return [
        {"query": "q000-wrs", "workload": "wrs", "strategy": "local",
         "world": 2, "us_per_call": 5e5, "tau": 1024, "epochs": 3,
         "wait_ticks": 0, "devices_leased": 2, "placement_wait_ticks": 0},
        {"query": "q001-triangles", "workload": "triangles",
         "strategy": "barrier", "world": 1, "us_per_call": 8e5, "tau": 640,
         "epochs": 5, "wait_ticks": 2, "devices_leased": 1,
         "placement_wait_ticks": 1},
    ]


def _serve_doc(rows):
    return {"schema_version": SCHEMA_VERSION, "suite": "serve",
            "kind": "serve", "jax_version": "0.4.37", "platform": "cpu",
            "created_unix": 0.0, "scale": "conformance",
            "rows": [dict(r) for r in rows]}


def test_kind_defaults_to_instances_for_old_artifacts():
    """Artifacts written before the kind field existed stay valid."""
    doc = _doc(_rows())
    assert "kind" not in doc
    assert doc_kind(doc) == "instances"
    assert not validate_bench(doc)


def test_serve_roundtrip_and_summary(tmp_path):
    path = write_bench("serve", _serve_rows(), out_dir=tmp_path,
                       kind="serve")
    doc = load_bench(path)
    assert doc_kind(doc) == "serve" and len(doc["rows"]) == 2
    out = summarize(doc)
    assert "kind=serve" in out
    assert "q000-wrs" in out and "pool: 2 queries" in out


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.update(kind="warp"), "kind"),
    (lambda d: d["rows"][0].pop("query"), "query"),
    (lambda d: d["rows"][0].update(epochs=0), "epochs"),
    (lambda d: d["rows"][0].update(wait_ticks=-1), "wait_ticks"),
    (lambda d: d["rows"][1].update(query="q000-wrs"), "duplicate"),
    (lambda d: d["rows"][0].update(tau=0), "tau"),
    (lambda d: d["rows"][0].pop("devices_leased"), "devices_leased"),
    (lambda d: d["rows"][0].update(devices_leased=-1), "devices_leased"),
    (lambda d: d["rows"][1].update(placement_wait_ticks=-2),
     "placement_wait_ticks"),
])
def test_serve_validator_catches(mutate, needle):
    doc = _serve_doc(_serve_rows())
    mutate(doc)
    errs = validate_bench(doc)
    assert errs and any(needle in e for e in errs), errs


def test_serve_v1_artifacts_stay_valid_without_placement_fields():
    """Schema bump is backward-compatible: pre-placement (v1) serve rows
    lack devices_leased/placement_wait_ticks and still validate; the same
    rows under v2 do not, and negative values fail under both."""
    doc = _serve_doc(_serve_rows())
    doc["schema_version"] = 1
    for row in doc["rows"]:
        del row["devices_leased"], row["placement_wait_ticks"]
    assert not validate_bench(doc)
    v2 = json.loads(json.dumps(doc))
    v2["schema_version"] = SCHEMA_VERSION
    errs = validate_bench(v2)
    assert errs and any("devices_leased" in e for e in errs)
    doc["rows"][0]["placement_wait_ticks"] = -1
    assert any("placement_wait_ticks" in e for e in validate_bench(doc))


def test_serve_summary_prints_device_utilization():
    doc = _serve_doc(_serve_rows())
    doc["pool_devices"] = 4
    from benchmarks.perf_summary import summarize_serve
    out = summarize_serve(doc)
    assert "device utilization" in out
    assert "4-device pool" in out


def test_serve_rows_do_not_need_speedup_field():
    """The BARRIER/speedup coupling is an instances-kind invariant only."""
    doc = _serve_doc(_serve_rows())
    assert not validate_bench(doc)


def test_serve_diff_joins_on_query_id():
    old = _serve_doc(_serve_rows())
    new_rows = _serve_rows()
    new_rows[0]["us_per_call"] = 5e6           # 10x: regression
    new_rows[1]["tau"] = 999                   # semantics changed
    rep = diff_bench(old, _serve_doc(new_rows), rtol=0.25, min_us=50.0)
    assert not rep["ok"]
    assert rep["regressions"] == ["q000-wrs"]
    assert rep["tau_changes"] == ["q001-triangles"]


def test_diff_refuses_mixed_kinds():
    with pytest.raises(ValueError, match="kind"):
        diff_bench(_doc(_rows()), _serve_doc(_serve_rows()))


def test_diff_cli_exit_codes(tmp_path):
    old = write_bench("instances", attach_speedups(_rows()),
                      out_dir=tmp_path / "old")
    worse = _rows()
    worse[0]["us_per_call"] = 999.0
    new = write_bench("instances", attach_speedups(worse),
                      out_dir=tmp_path / "new")
    assert _cli(["diff", str(old), str(old)]) == 0
    assert _cli(["diff", str(old), str(new)]) == 1
    assert _cli(["diff", str(old)]) == 2          # missing operand
    assert _cli(["validate", str(old), str(new)]) == 0

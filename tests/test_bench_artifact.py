"""BENCH_*.json perf-artifact pipeline: writer/validator round-trip, schema
violations, speedup attachment, and the perf summary."""

import json
import sys
from pathlib import Path

import pytest

# benchmarks/ is a sibling of tests/ — importable from the repo root
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.artifact import (SCHEMA_VERSION, attach_speedups,  # noqa: E402
                                 load_bench, validate_bench, write_bench)
from benchmarks.perf_summary import summarize  # noqa: E402


def _rows():
    return [
        {"workload": "wrs", "strategy": "barrier", "world": 1,
         "us_per_call": 100.0, "tau": 1024},
        {"workload": "wrs", "strategy": "local", "world": 1,
         "us_per_call": 50.0, "tau": 1024},
        {"workload": "diameter", "strategy": "indexed", "world": 4,
         "us_per_call": 75.0, "tau": 16},
    ]


def test_attach_speedups():
    rows = attach_speedups(_rows())
    by = {(r["workload"], r["strategy"]): r for r in rows}
    assert by[("wrs", "barrier")]["speedup_vs_barrier"] == 1.0
    assert by[("wrs", "local")]["speedup_vs_barrier"] == 2.0
    # no BARRIER baseline for that (workload, world) cell → null
    assert by[("diameter", "indexed")]["speedup_vs_barrier"] is None


def test_write_load_roundtrip(tmp_path):
    path = write_bench("instances", attach_speedups(_rows()),
                       out_dir=tmp_path, scale="conformance")
    assert path.name == "BENCH_instances.json"
    doc = load_bench(path)
    assert doc["schema_version"] == SCHEMA_VERSION
    assert doc["suite"] == "instances" and len(doc["rows"]) == 3
    assert {"jax_version", "platform", "created_unix"} <= set(doc)
    assert not validate_bench(doc)


def test_writer_refuses_invalid_rows(tmp_path):
    bad = [{"workload": "wrs", "strategy": "warp", "world": 1,
            "us_per_call": 1.0, "tau": 1, "speedup_vs_barrier": None}]
    with pytest.raises(ValueError, match="strategy"):
        write_bench("instances", bad, out_dir=tmp_path)


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.pop("jax_version"), "jax_version"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d.update(scale="huge"), "scale"),
    (lambda d: d.update(rows=[]), "empty"),
    (lambda d: d["rows"][0].pop("tau"), "tau"),
    (lambda d: d["rows"][0].update(us_per_call=0.0), "us_per_call"),
    (lambda d: d["rows"][0].update(world=0), "world"),
    (lambda d: d["rows"][1].update(speedup_vs_barrier=None), "null"),
    (lambda d: d["rows"][2].update(speedup_vs_barrier=3.0), "without"),
])
def test_validator_catches(tmp_path, mutate, needle):
    path = write_bench("instances", attach_speedups(_rows()),
                       out_dir=tmp_path)
    doc = json.loads(path.read_text())
    mutate(doc)
    errs = validate_bench(doc)
    assert errs and any(needle in e for e in errs), errs


def test_perf_summary_output(tmp_path):
    path = write_bench("instances", attach_speedups(_rows()),
                       out_dir=tmp_path)
    out = summarize(load_bench(path))
    assert "suite=instances" in out
    assert "best[wrs]: local W=1 at 2.00x" in out

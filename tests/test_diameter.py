"""Diameter workload: exact oracle, double-sweep bounds, histogram frames,
and the eccentricity-gap stopping rule."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frames import StateFrame
from repro.core.stopping import EccentricityGapCondition
from repro.graphs import (diameter_estimate, diameter_exact, double_sweep,
                          erdos_renyi, grid2d, make_sweep_sample_fn)


def test_diameter_exact_grid_closed_form():
    for rows, cols in ((3, 4), (5, 5), (2, 7)):
        g = grid2d(rows, cols)
        assert diameter_exact(g) == (rows - 1) + (cols - 1)


def test_diameter_exact_er_matches_bfs_bounds():
    g = erdos_renyi(40, 120, seed=5)
    diam = diameter_exact(g)
    # any double sweep: ecc(u) ≤ diam ≤ 2·ecc(v)
    for v in (0, 7, 23):
        ecc_v, ecc_u = double_sweep(g, jnp.int32(v), max_levels=g.n)
        assert int(ecc_u) <= diam <= 2 * int(ecc_v)


def test_double_sweep_grid_bounds():
    g = grid2d(5, 5)
    # from the center (ecc = 4): u is a corner, ecc(u) = 8 = diam → gap 0
    ecc_v, ecc_u = double_sweep(g, jnp.int32(12), max_levels=g.n)
    assert int(ecc_v) == 4 and int(ecc_u) == 8
    # from a corner: the sweep still finds the true diameter lower bound
    ecc_v, ecc_u = double_sweep(g, jnp.int32(0), max_levels=g.n)
    assert int(ecc_v) == 8 and int(ecc_u) == 8


def test_sweep_sample_fn_histogram_and_certs():
    g = grid2d(5, 5)
    fn = make_sweep_sample_fn(g, batch=32, gap=0, pad_to=28)
    frame, _ = fn(jax.random.key(0), None)
    hist = np.asarray(frame.data["ecc_hist"])
    assert int(frame.num) == 32 and hist.sum() == 32
    # every double sweep on a grid lands the exact diameter lower bound
    assert diameter_estimate(hist) == 8.0
    # certificates are exactly the draws of the unique central vertex
    assert 0 <= int(frame.data["cert"]) <= 32


def test_eccentricity_gap_condition():
    cond = EccentricityGapCondition(gap=0, min_certs=1, max_samples=100)

    def frame(num, certs):
        return StateFrame(num=jnp.int32(num),
                          data={"cert": jnp.int32(certs),
                                "ecc_hist": jnp.zeros((8,), jnp.int32)})

    assert not bool(cond(frame(10, 0))[0])
    assert bool(cond(frame(10, 1))[0])       # certificate stops
    assert bool(cond(frame(100, 0))[0])      # static cap stops
    stop, aux = cond(frame(10, 3))
    assert int(aux["certs"]) == 3 and int(aux["gap"]) == 0

"""End-to-end system tests: training loop with checkpoint/restart + failure
injection, serve loop, sharded epoch engine on a mesh, and a subprocess
mini dry-run."""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_cli(mod, *args, timeout=600):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    return subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        env=env, timeout=timeout, cwd=ROOT)


def test_train_loop_end_to_end(tmp_path):
    r = run_cli("repro.launch.train", "--arch", "smollm-360m-reduced",
                "--steps", "8", "--batch", "4", "--seq", "32",
                "--micro", "2", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "4", "--log-every", "4")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done" in r.stdout
    from repro.checkpoint import latest_step
    assert latest_step(tmp_path) == 8


def test_train_resume_after_preemption(tmp_path):
    r1 = run_cli("repro.launch.train", "--arch", "smollm-360m-reduced",
                 "--steps", "10", "--batch", "4", "--seq", "32",
                 "--micro", "1", "--ckpt-dir", str(tmp_path),
                 "--ckpt-every", "3", "--preempt-at", "5")
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert "PREEMPTION" in r1.stdout
    r2 = run_cli("repro.launch.train", "--arch", "smollm-360m-reduced",
                 "--steps", "10", "--batch", "4", "--seq", "32",
                 "--micro", "1", "--ckpt-dir", str(tmp_path), "--resume")
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed at step 5" in r2.stdout
    assert "done" in r2.stdout


def test_serve_generate_and_adaptive_eval():
    r = run_cli("repro.launch.serve", "--arch", "smollm-360m-reduced",
                "--batch", "2", "--prompt-len", "8", "--gen", "4")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "generated" in r.stdout
    r = run_cli("repro.launch.serve", "--arch", "smollm-360m-reduced",
                "--adaptive-eval", "--eps", "0.5", "--delta", "0.2",
                "--seq", "16", "--batch", "2")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "adaptive eval" in r.stdout


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """The real dry-run entrypoint on the smallest cell (512 virtual
    devices in a subprocess — must not leak into this process)."""
    r = run_cli("repro.launch.dryrun", "--arch", "smollm-360m",
                "--shape", "decode_32k", "--no-diff", timeout=900)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "memory_analysis" in r.stdout
    assert len(jax.devices()) == 1  # flag must not leak


def test_sharded_epoch_engine_on_mesh():
    """run_sharded on a 1-device mesh (semantics identical to vmap path)."""
    from repro.core.epoch import EpochConfig, run_sharded
    from repro.core.frames import FrameStrategy, StateFrame
    from repro.core.stopping import HoeffdingCondition

    def sample_fn(key, carry):
        x = (jax.random.uniform(key, (4, 8)) < 0.5).astype(jnp.int32)
        return StateFrame(num=jnp.int32(4), data=x.sum(0)), carry

    mesh = jax.make_mesh((1,), ("workers",))
    cfg = EpochConfig(strategy=FrameStrategy.LOCAL_FRAME,
                      rounds_per_epoch=2, max_epochs=500)
    st = run_sharded(sample_fn, HoeffdingCondition(eps=0.1, delta=0.1),
                     jnp.zeros((8,), jnp.int32), None, 0, mesh, "workers",
                     cfg)
    assert bool(np.asarray(st.stop).reshape(-1)[0])
    assert int(np.asarray(st.total.num).reshape(-1)[0]) >= 149

"""Pipeline parallelism: GPipe schedule equals the sequential layer stack."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.pipeline import pipeline_forward

L, D, M, MB = 8, 16, 6, 4
key = jax.random.key(0)
w = jax.random.normal(key, (L, D, D)) * 0.3
b = jax.random.normal(jax.random.fold_in(key, 1), (L, D)) * 0.1
params = {"w": w, "b": b}
x = jax.random.normal(jax.random.fold_in(key, 2), (M, MB, D))

def layer(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])

# sequential reference
ref = x
for i in range(L):
    ref = layer(jax.tree.map(lambda a: a[i], params), ref)

for S in (2, 4):
    mesh = jax.make_mesh((S,), ("stage",))
    out = pipeline_forward(layer, params, x, mesh, axis="stage")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    print(f"S={S} pipeline == sequential")
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600, cwd=ROOT)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    assert "PIPELINE_OK" in r.stdout

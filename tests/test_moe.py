"""MoE routing semantics: one-hot vs sort dispatch equivalence, capacity
dropping, load-balance aux loss."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs.qwen3_moe_235b_a22b as q
from repro.models.layers import init_params
from repro.models.moe import moe_capacity, moe_defs, moe_ffn, route_topk


@pytest.fixture(scope="module")
def setup():
    cfg = q.reduced()
    p = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    return cfg, p, x


def test_sort_equals_onehot(setup):
    cfg, p, x = setup
    y1, a1 = moe_ffn(p, x, dataclasses.replace(cfg, moe_dispatch="onehot"),
                     group_size=64)
    y2, a2 = moe_ffn(p, x, dataclasses.replace(cfg, moe_dispatch="sort"),
                     group_size=64)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=3e-2, rtol=3e-2)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_sort_equals_onehot_across_groups(setup):
    cfg, p, x = setup
    for g in (32, 128):
        y1, _ = moe_ffn(p, x, dataclasses.replace(cfg, moe_dispatch="onehot"),
                        group_size=g)
        y2, _ = moe_ffn(p, x, dataclasses.replace(cfg, moe_dispatch="sort"),
                        group_size=g)
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32),
                                   atol=3e-2, rtol=3e-2)


def test_route_topk_respects_capacity():
    logits = jnp.zeros((1, 16, 4))  # uniform → round-robin-ish top-k ties
    disp, comb, aux = route_topk(logits, k=2, capacity=4)
    # no expert receives more than capacity slots
    per_expert = np.asarray(disp).sum(axis=(1, 3))  # (G, E)
    assert per_expert.max() <= 4 + 1e-6
    # combine weights only where dispatched
    assert np.all((np.asarray(comb) > 0) <= (np.asarray(disp) > 0))


def test_capacity_formula():
    cfg = q.reduced()
    c = moe_capacity(cfg, 512)
    expect = int(512 * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1
    assert c >= expect
    assert c % 8 == 0


def test_aux_loss_balanced_vs_skewed():
    """Aux loss is ~1 for uniform routing, larger when skewed."""
    G, S, E, k = 1, 256, 8, 2
    uniform = jax.random.normal(jax.random.key(0), (G, S, E)) * 0.01
    skewed = uniform.at[..., 0].add(10.0)
    _, _, a_u = route_topk(uniform, k, 64)
    _, _, a_s = route_topk(skewed, k, 64)
    assert float(a_u) < float(a_s)
    assert float(a_u) == pytest.approx(1.0, abs=0.3)

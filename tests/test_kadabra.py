"""KADABRA end-to-end: (ε,δ) accuracy vs the exact Brandes oracle for every
parallelization strategy — the paper's correctness claim (§2.3, Prop. 1)."""

import numpy as np
import pytest

from repro.core.frames import FrameStrategy
from repro.graphs import (KadabraParams, barabasi_albert, brandes_exact,
                          erdos_renyi, grid2d, preprocess, run_kadabra)


@pytest.fixture(scope="module")
def er_graph():
    g = erdos_renyi(60, 150, seed=1)
    return g, brandes_exact(g)


@pytest.mark.parametrize("strategy,world", [
    (FrameStrategy.LOCK, 1),
    (FrameStrategy.BARRIER, 4),
    (FrameStrategy.LOCAL_FRAME, 1),
    (FrameStrategy.LOCAL_FRAME, 4),
    (FrameStrategy.SHARED_FRAME, 4),
    (FrameStrategy.INDEXED_FRAME, 4),
])
def test_eps_accuracy(er_graph, strategy, world):
    g, exact = er_graph
    eps = 0.05
    params = KadabraParams(eps=eps, delta=0.1, batch=32, rounds_per_epoch=2,
                           max_epochs=2000)
    btilde, st, pre = run_kadabra(g, params, strategy=strategy, world=world,
                                  seed=3)
    err = np.abs(btilde - exact).max()
    # δ=0.1 failure probability; the fixed seed keeps this deterministic
    assert err <= eps, f"{strategy} W={world}: max err {err} > ε"


def test_preprocessing_vertex_diameter_bound():
    g = grid2d(6, 6)
    pre = preprocess(g, eps=0.05, delta=0.1)
    # true diameter 10 ⇒ VD=11; double-sweep UB must dominate it
    assert pre.vd_upper >= 11
    assert pre.omega > 0


def test_indexed_frame_reproducible_result():
    g = barabasi_albert(50, 2, seed=4)
    params = KadabraParams(eps=0.08, delta=0.1, batch=16, rounds_per_epoch=2,
                           max_epochs=1500)
    b1, st1, _ = run_kadabra(g, params,
                             strategy=FrameStrategy.INDEXED_FRAME,
                             world=2, seed=9)
    b2, st2, _ = run_kadabra(g, params,
                             strategy=FrameStrategy.INDEXED_FRAME,
                             world=8, seed=9)
    np.testing.assert_array_equal(b1, b2)


def test_termination_uses_fewer_samples_than_omega_sometimes():
    """The adaptive part must engage: on an easy instance stopping happens
    before ω (otherwise we built static sampling, not ADS)."""
    g = erdos_renyi(40, 400, seed=2)  # dense ⇒ tiny BC values ⇒ easy
    params = KadabraParams(eps=0.05, delta=0.1, batch=64, rounds_per_epoch=1,
                           max_epochs=2000)
    btilde, st, pre = run_kadabra(g, params,
                                  strategy=FrameStrategy.LOCAL_FRAME,
                                  world=1, seed=0)
    tau = float(np.asarray(st.total.num).reshape(-1)[0])
    assert tau < pre.omega, (tau, pre.omega)

"""Cross-strategy conformance: every registered ADS instance under every
FrameStrategy × W ∈ {1, 2, 4} (the paper's invariants, per workload), plus
property tests for the algebra INDEXED_FRAME determinism rests on."""

import functools

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.conformance import run_conformance
from repro.core.frames import (FrameStrategy, StateFrame, accumulate,
                               combine, zeros_like_frame)
from repro.core.instances import available_instances

INSTANCES = ("kadabra", "triangles", "reachability", "wrs", "diameter",
             "gradvar")
WORLDS = (1, 2, 4)
# Seed 0 certifies every cell in the fast tier; the slow tier re-certifies
# at seeds 1 and 2 so no invariant is blessed at a single lucky seed.
EXTRA_SEEDS = (1, 2)


@functools.lru_cache(maxsize=None)
def report(name, seed=0):
    """One engine sweep per (instance, seed), shared by all asserts."""
    return run_conformance(name, worlds=WORLDS, seed=seed)


def test_builtin_instances_registered():
    for name in INSTANCES:
        assert name in available_instances()


@pytest.mark.parametrize("world", WORLDS)
@pytest.mark.parametrize("strategy", list(FrameStrategy),
                         ids=lambda s: s.name)
@pytest.mark.parametrize("instance", INSTANCES)
def test_cell(instance, strategy, world):
    """Termination + Prop.-1 sample-count consistency + (ε,δ) accuracy vs
    both the exact oracle and the W=1 sequential run."""
    rep = report(instance)
    cell = next(c for c in rep.cells
                if c.strategy == strategy and c.world == world)
    assert cell.ok, "\n".join(cell.failures)


@pytest.mark.parametrize("instance", INSTANCES)
def test_cross_invariants(instance):
    """INDEXED_FRAME bit-identity across W; SHARED_FRAME shard reassembly
    equals the replicated LOCAL_FRAME total."""
    rep = report(instance)
    assert not rep.cross_failures, "\n".join(rep.cross_failures)


@pytest.mark.parametrize("instance", INSTANCES)
def test_indexed_frame_bit_identical_estimates(instance):
    """§D.2 acceptance: the INDEXED_FRAME estimate (b̃ for KADABRA) is
    bit-identical — not merely close — for W ∈ {1, 2, 4}."""
    rep = report(instance)
    ests = [c.estimate for c in rep.cells
            if c.strategy == FrameStrategy.INDEXED_FRAME]
    assert len(ests) == len(WORLDS)
    for e in ests[1:]:
        np.testing.assert_array_equal(e, ests[0])


@pytest.mark.slow
@pytest.mark.parametrize("seed", EXTRA_SEEDS)
@pytest.mark.parametrize("instance", INSTANCES)
def test_multi_seed_sweep(instance, seed):
    """The full per-instance grid (all strategies × W, incl. the cross-cell
    INDEXED determinism and SHARED reassembly invariants) re-certified at
    non-default seeds — run_conformance threads the seed into every cell
    *and* the W=1 sequential reference run."""
    rep = report(instance, seed)
    assert rep.ok, rep.summary()


def test_run_all_passes_seed_through():
    """run_all(seed=s) must hand s to every per-instance sweep (a dropped
    seed would silently re-certify seed 0 three times)."""
    import repro.core.conformance as conf

    seen = []

    def spy(name, **kw):
        seen.append((name, kw.get("seed")))
        return conf.ConformanceReport(instance=name, cells=[],
                                      cross_failures=[])

    orig = conf.run_conformance
    conf.run_conformance = spy
    try:
        conf.run_all(seed=17, worlds=(1,))
    finally:
        conf.run_conformance = orig
    from repro.core.instances import available_instances
    assert [n for n, _ in seen] == sorted(available_instances())
    assert all(s == 17 for _, s in seen)


# ------------------------------------------------------------------ algebra
# INDEXED_FRAME determinism rests on ∘ being associative and commutative:
# per-worker deltas may be *produced* in any order, but the checker consumes
# them by frame index, so any combine/accumulate order must yield the same
# totals.  Property-checked over random frame batches and permutations.

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 12), st.integers(0, 2 ** 31 - 1))
def test_combine_accumulate_order_invariance(w, n, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 100, size=(w, n))
    nums = rng.integers(1, 10, size=(w,))
    stacked = StateFrame(num=jnp.asarray(nums, jnp.int32),
                         data=jnp.asarray(data, jnp.int32))
    total = accumulate(stacked)
    perm = rng.permutation(w)
    permuted = StateFrame(num=jnp.asarray(nums[perm], jnp.int32),
                          data=jnp.asarray(data[perm], jnp.int32))
    total_perm = accumulate(permuted)
    assert int(total.num) == int(total_perm.num)
    np.testing.assert_array_equal(np.asarray(total.data),
                                  np.asarray(total_perm.data))
    # left-fold in permuted arrival order == batched accumulate
    fold = zeros_like_frame(jnp.zeros((n,), jnp.int32))
    for i in perm:
        fold = combine(fold, StateFrame(num=jnp.int32(int(nums[i])),
                                        data=jnp.asarray(data[i], jnp.int32)))
    assert int(fold.num) == int(total.num)
    np.testing.assert_array_equal(np.asarray(fold.data),
                                  np.asarray(total.data))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(2, 10), st.integers(0, 2 ** 31 - 1))
def test_indexed_prefix_check_order_independent_of_arrival(w, n, seed):
    """The INDEXED prefix walk (combine frame 0, check, combine frame 1, …)
    gives the same stopping prefix no matter how the frames were combined
    into intermediate accumulations beforehand."""
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 50, size=(w, n))
    thresh = float(rng.integers(1, max(2, int(data.sum()))))

    def prefix_stop(order_hint):
        # the checker is *defined* on index order; order_hint only changes
        # how we build each prefix total (pairwise vs left-fold).
        total = zeros_like_frame(jnp.zeros((n,), jnp.int32))
        for j in range(w):
            f = StateFrame(num=jnp.int32(1), data=jnp.asarray(data[j],
                                                              jnp.int32))
            total = combine(f, total) if order_hint and j % 2 else \
                combine(total, f)
            if float(np.asarray(total.data).sum()) >= thresh:
                return j, np.asarray(total.data).copy()
        return w, np.asarray(total.data).copy()

    ja, da = prefix_stop(False)
    jb, db = prefix_stop(True)
    assert ja == jb
    np.testing.assert_array_equal(da, db)

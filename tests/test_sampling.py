"""Weighted random sampling: alias-table exactness, the alias-draw kernel
vs its oracle, and the relative-error stopping rule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.frames import StateFrame
from repro.core.stopping import RelativeErrorCondition
from repro.kernels import ref
from repro.kernels.alias_draw import alias_draw
from repro.sampling import (alias_draw_probabilities, build_alias_table,
                            make_weighted_sample_fn, weighted_mean_exact)


# ----------------------------------------------------------------- alias table
def test_alias_table_exact_probabilities():
    """Vose invariant: prob[i] + Σ_{j: alias[j]=i}(1−prob[j]) = n·wᵢ/Σw."""
    rng = np.random.default_rng(0)
    w = rng.pareto(1.5, size=257) + 1e-4
    table = build_alias_table(w)
    p = alias_draw_probabilities(table)
    np.testing.assert_allclose(p, w / w.sum(), rtol=1e-5, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_alias_table_exact_probabilities_property(n, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.0, 10.0, size=n) + 1e-6
    p = alias_draw_probabilities(build_alias_table(w))
    np.testing.assert_allclose(p, w / w.sum(), rtol=1e-5, atol=1e-9)
    assert abs(p.sum() - 1.0) < 1e-6


def test_alias_table_degenerate_and_invalid():
    t = build_alias_table(np.asarray([3.0]))
    np.testing.assert_allclose(alias_draw_probabilities(t), [1.0])
    # a zero-weight item must never be drawn
    t = build_alias_table(np.asarray([1.0, 0.0, 1.0]))
    p = alias_draw_probabilities(t)
    assert p[1] < 1e-12
    with pytest.raises(ValueError):
        build_alias_table(np.zeros(4))
    with pytest.raises(ValueError):
        build_alias_table(np.asarray([1.0, -2.0]))
    with pytest.raises(ValueError):
        build_alias_table(np.asarray([1.0, np.inf]))
    with pytest.raises(ValueError):
        build_alias_table(np.zeros(0))


# --------------------------------------------------------------- alias kernel
@pytest.mark.parametrize("n,b,block_b", [(7, 64, 16), (256, 1000, 256),
                                         (33, 4096, 4096), (5, 3, 64)])
def test_alias_draw_kernel_matches_ref(n, b, block_b):
    rng = np.random.default_rng(n * b)
    table = build_alias_table(rng.pareto(1.2, size=n) + 1e-4)
    k1, k2 = jax.random.split(jax.random.key(b))
    u1 = jax.random.uniform(k1, (b,))
    u2 = jax.random.uniform(k2, (b,))
    got = alias_draw(table.prob, table.alias, u1, u2, block_b=block_b,
                     interpret=True)
    exp = ref.alias_draw_ref(table.prob, table.alias, u1, u2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    assert np.all(np.asarray(got) >= 0) and np.all(np.asarray(got) < n)


def test_alias_draw_empirical_distribution():
    """Large-sample frequencies match the weights (4σ binomial bands)."""
    rng = np.random.default_rng(1)
    w = rng.pareto(1.5, size=16) + 0.05
    table = build_alias_table(w)
    b = 200_000
    k1, k2 = jax.random.split(jax.random.key(0))
    u1 = jax.random.uniform(k1, (b,))
    u2 = jax.random.uniform(k2, (b,))
    idx = np.asarray(ref.alias_draw_ref(table.prob, table.alias, u1, u2))
    freq = np.bincount(idx, minlength=16) / b
    p = w / w.sum()
    sigma = np.sqrt(p * (1 - p) / b)
    assert np.all(np.abs(freq - p) < 4.0 * sigma + 1e-4)


# ------------------------------------------------------------------ sample fn
def test_weighted_sample_fn_frame_contents():
    rng = np.random.default_rng(2)
    w = rng.pareto(1.5, size=32) + 1e-3
    values_q = jnp.asarray(rng.integers(8, 32, size=32), jnp.int32)
    table = build_alias_table(w)
    fn = make_weighted_sample_fn(table, values_q, batch=512, pad_to=32)
    frame, _ = fn(jax.random.key(3), None)
    hist = np.asarray(frame.data["hist"])
    assert int(frame.num) == 512 and hist.sum() == 512
    # moments must equal the histogram-weighted sums exactly (integer frames)
    v = np.asarray(values_q, np.int64)
    assert int(frame.data["s1"]) == int((hist * v).sum())
    assert int(frame.data["s2"]) == int((hist * v * v).sum())


def test_weighted_mean_exact_matches_definition():
    w = np.asarray([1.0, 3.0])
    vq = np.asarray([8, 16])
    got = weighted_mean_exact(w, vq, value_scale=32)
    assert abs(got - (0.25 * 8 / 32 + 0.75 * 16 / 32)) < 1e-12


# ------------------------------------------------------- relative-error rule
def _moment_frame(num, mean, var, scale=1.0):
    s1 = mean * num * scale
    s2 = (var + mean ** 2) * num * scale ** 2
    return StateFrame(num=jnp.int32(num),
                      data={"s1": jnp.float32(s1), "s2": jnp.float32(s2),
                            "hist": jnp.zeros((4,), jnp.int32)})


def test_relative_error_condition_stops_on_tight_mean():
    cond = RelativeErrorCondition(rtol=0.05, delta=0.1)
    assert not bool(cond(_moment_frame(50, 0.5, 0.05))[0])
    assert bool(cond(_moment_frame(200_000, 0.5, 0.05))[0])


def test_relative_error_condition_scale_invariance():
    """Quantized frames (s1=Σxq, s2=Σxq²) give the same verdict and mean."""
    plain = RelativeErrorCondition(rtol=0.05, delta=0.1)
    scaled = RelativeErrorCondition(rtol=0.05, delta=0.1, scale=32.0)
    fa = _moment_frame(5000, 0.5, 0.02)
    fb = _moment_frame(5000, 0.5, 0.02, scale=32.0)
    sa, aa = plain(fa)
    sb, ab = scaled(fb)
    assert bool(sa) == bool(sb)
    np.testing.assert_allclose(float(aa["mean"]), float(ab["mean"]),
                               rtol=1e-5)


def test_relative_error_condition_max_samples_cap():
    cond = RelativeErrorCondition(rtol=1e-9, delta=0.1, max_samples=1000)
    assert not bool(cond(_moment_frame(999, 0.5, 0.1))[0])
    assert bool(cond(_moment_frame(1000, 0.5, 0.1))[0])

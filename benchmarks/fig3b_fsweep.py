"""Fig. 3b analog: shared-frame F parameter sweep.

The paper varies the number F of shared SF pairs on 36 cores: small F
minimizes memory bandwidth at the cost of atomics contention.  Our TPU
mapping (DESIGN.md §2): F = number of frame shards; F = W is a plain
reduce-scatter, F < W adds a cross-group all-reduce of n/F-sized partials.
We measure wall time AND report the per-worker frame memory, reproducing the
paper's memory/time trade-off axis."""

from __future__ import annotations

from benchmarks.common import emit, instances, timeit
from repro.core.epoch import EpochConfig, run_virtual
from repro.core.frames import FrameStrategy, shard_frame_pad
from repro.core.stopping import KadabraCondition
from repro.graphs import frame_template, make_sample_fn, preprocess


def run() -> None:
    g = instances()["er-social-s"]()
    pre = preprocess(g, eps=0.05, delta=0.1)
    W = 8
    for F in (1, 2, 4, 8):
        pad = shard_frame_pad(g.n, F)
        sample_fn = make_sample_fn(g, pre, batch=16, pad_to=pad)
        cond = KadabraCondition(eps=0.05, delta=0.1, omega=pre.omega,
                                n_vertices=g.n)
        cfg = EpochConfig(strategy=FrameStrategy.SHARED_FRAME,
                          rounds_per_epoch=4, max_epochs=3000)
        t = timeit(lambda F=F, pad=pad, s=sample_fn, c=cond, cf=cfg:
                   run_virtual(s, c, frame_template(g, pad), None, 0, W, cf,
                               frame_shards=F).total.num,
                   warmup=1, iters=2)
        mem_per_worker = pad // F * 4  # int32 shard bytes
        emit(f"fig3b/shared_frame/W={W}/F={F}", t,
             f"frame_bytes_per_worker={mem_per_worker}")


if __name__ == "__main__":
    run()

"""Shared benchmark helpers: timing, instance set, CSV emission."""

from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` (blocking on jax arrays)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds*1e6:.1f},{derived}")


def instances():
    """Synthetic instance set matched to the paper's categories (App. E)."""
    from repro.graphs import barabasi_albert, erdos_renyi, grid2d
    return {
        "er-social-s": lambda: erdos_renyi(300, 1200, seed=0),
        "ba-hyperlink-s": lambda: barabasi_albert(300, 3, seed=1),
        "grid-road-s": lambda: grid2d(18, 17),
        "er-social-m": lambda: erdos_renyi(1000, 5000, seed=2),
    }

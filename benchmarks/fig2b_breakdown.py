"""Fig. 2b analog: preprocessing vs ADS running-time breakdown as ε shrinks.

The paper's point: for small ε the ADS phase dominates, so parallelizing ADS
is what matters.  We measure both phases of our KADABRA on three instance
categories for ε ∈ {0.1, 0.05, 0.03}."""

from __future__ import annotations

from benchmarks.common import emit, instances, timeit
from repro.core.frames import FrameStrategy
from repro.graphs import KadabraParams, preprocess, run_kadabra


def run() -> None:
    for name in ("er-social-s", "grid-road-s", "ba-hyperlink-s"):
        g = instances()[name]()
        t_pre = timeit(lambda: preprocess(g, eps=0.05, delta=0.1), iters=2)
        pre = preprocess(g, eps=0.05, delta=0.1)
        for eps in (0.1, 0.05, 0.03):
            params = KadabraParams(eps=eps, delta=0.1, batch=32,
                                   rounds_per_epoch=4, max_epochs=4000)
            t_ads = timeit(lambda: run_kadabra(
                g, params, strategy=FrameStrategy.LOCAL_FRAME, world=1,
                pre=pre)[0], warmup=1, iters=2)
            frac = t_ads / (t_ads + t_pre)
            emit(f"fig2b/{name}/eps={eps}", t_ads,
                 f"ads_fraction={frac:.2f};preproc_us={t_pre*1e6:.0f}")


if __name__ == "__main__":
    run()

"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3a,...]

Emits ``name,us_per_call,derived`` CSV rows (plus ``#`` commentary lines).

| module               | paper artifact                                  |
|----------------------|--------------------------------------------------|
| fig2a_baseline       | Fig. 2a — barrier baseline vs lock analog        |
| fig2b_breakdown      | Fig. 2b — preprocessing/ADS split vs ε           |
| fig3a_speedup        | Fig. 3a — epoch-based vs barrier (meas. + model) |
| fig3b_fsweep         | Fig. 3b — shared-frame F sweep                   |
| tables23_instances   | Tables 2–3 — per-instance absolute times         |
| bench_instances      | ADS registry sweep — workload × strategy × W;    |
|                      | writes the BENCH_instances.json perf artifact    |
| bench_serve          | serving scheduler over a mixed query stream;     |
|                      | writes the BENCH_serve.json perf artifact        |
| roofline_table       | §Roofline — 40-cell dry-run aggregate            |
| bench_adaptive       | §3.1 (ours) — adaptive grad-accum savings        |
"""

from __future__ import annotations

import argparse
import importlib
import os
import subprocess
import sys
import time
import traceback

MODULES = [
    "fig2a_baseline",
    "fig2b_breakdown",
    "fig3a_speedup",
    "fig3b_fsweep",
    "tables23_instances",
    "bench_instances",
    "bench_serve",
    "roofline_table",
    "bench_adaptive",
]


def _run_inline(name: str) -> None:
    mod = importlib.import_module(f"benchmarks.{name}")
    mod.run()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benchmark modules")
    ap.add_argument("--inline", action="store_true",
                    help="run in-process (default: one subprocess per module"
                         " — isolates XLA jit state between suites)")
    args = ap.parse_args()
    only = {m.strip() for m in args.only.split(",") if m.strip()}
    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            if args.inline:
                _run_inline(name)
            else:
                r = subprocess.run(
                    [sys.executable, "-m", "benchmarks.run", "--inline",
                     "--only", name],
                    capture_output=True, text=True, timeout=1800,
                    env=dict(os.environ))
                # forward CSV rows, drop the child's header/section lines
                for line in r.stdout.splitlines():
                    if line.startswith(("name,us_per_call", "# ---",
                                        "# all benchmarks")):
                        continue
                    print(line)
                if r.returncode != 0:
                    sys.stderr.write(r.stderr[-3000:])
                    failed.append(name)
                    continue
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}")
        return 1
    print("# all benchmarks complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 2a analog: barrier baseline ("OpenMP") vs lock analog (original
KADABRA parallelization).

The paper's Fig. 2a shows its OpenMP baseline beating the original lock-based
implementation (6.9× at 1 core, 13.5× at 32).  Our measurable analog on one
CPU: the LOCK strategy checks the stopping condition (an O(n) pass + a
reduce) after *every* round, the BARRIER strategy after N rounds — the
speedup isolates exactly the synchronization/checking overhead the paper
attributes to the lock."""

from __future__ import annotations

from benchmarks.common import emit, instances, timeit
from repro.core.frames import FrameStrategy
from repro.graphs import KadabraParams, preprocess, run_kadabra


def run() -> None:
    g = instances()["er-social-s"]()
    pre = preprocess(g, eps=0.05, delta=0.1)
    base = dict(eps=0.05, delta=0.1, batch=16, max_epochs=3000)

    def run_strategy(strategy, rounds, world):
        params = KadabraParams(rounds_per_epoch=rounds, **base)
        return lambda: run_kadabra(g, params, strategy=strategy, world=world,
                                   pre=pre)[0]

    for world in (1, 4):
        t_lock = timeit(run_strategy(FrameStrategy.LOCK, 1, 1), iters=3) \
            if world == 1 else None
        t_bar = timeit(run_strategy(FrameStrategy.BARRIER, 8, world), iters=3)
        if t_lock is not None:
            emit(f"fig2a/lock_analog/W=1", t_lock, "checks_every_round")
            emit(f"fig2a/barrier/W=1", t_bar,
                 f"speedup_vs_lock={t_lock/t_bar:.2f}x")
        else:
            emit(f"fig2a/barrier/W={world}", t_bar, "")


if __name__ == "__main__":
    run()

"""Fig. 3a analog: epoch-based algorithms vs the barrier baseline.

Two complementary measurements (one CPU core cannot show real parallel
speedup, so we separate the two factors that produce Fig. 3a):

1. **Measured overhead** — wall time per sample of each strategy at W=4
   virtual workers on CPU.  Differences isolate the synchronization
   structure (collective count, prefix checks) at identical sample work.

2. **Scaling model** — a discrete-event simulation parameterized by
   *measured* per-op costs (sample S, reduce R(n,W), check C(n)) replays
   each strategy's critical path for W = 1..64 and reports the parallel
   speedup curve.  Model:

   * BARRIER epoch:  K·S_max(W) + R(n,W) + C(n)   (samplers idle in R+C;
     S_max(W) = max of W iid sample times — straggler effect)
   * LOCAL epoch:    max(K·S_max(W), R(n,W)) + C(n)   (overlapped reduce)
   * SHARED epoch:   max(K·S_max(W), R(n/W·…)) + C(n/W) + ε_bit
   * INDEXED epoch:  max(K·S_max(W), AG(n,W)) + W·C(n)  (prefix checks)
   * LOCK round:     S_max(W) + R(n,W) + C(n)   (every round)

   The paper's 32-core numbers (local 15.9×, shared 18.1×, indexed 10.8×,
   OpenMP 6.3×) emerge from the same structure: barrier loses K·(R+C)/K on
   every epoch; shared wins once R's bandwidth term matters."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from benchmarks.common import emit, instances, timeit
from repro.core.frames import FrameStrategy
from repro.graphs import (KadabraParams, frame_template, make_sample_fn,
                          preprocess, run_kadabra)


def measured_overheads():
    g = instances()["er-social-s"]()
    pre = preprocess(g, eps=0.05, delta=0.1)
    out = {}
    for strat in (FrameStrategy.BARRIER, FrameStrategy.LOCAL_FRAME,
                  FrameStrategy.SHARED_FRAME, FrameStrategy.INDEXED_FRAME):
        params = KadabraParams(eps=0.05, delta=0.1, batch=16,
                               rounds_per_epoch=4, max_epochs=3000)
        t = timeit(lambda s=strat: run_kadabra(
            g, params, strategy=s, world=4, pre=pre)[0], warmup=1, iters=2)
        out[strat.value] = t
        emit(f"fig3a/measured/{strat.value}/W=4", t, "")
    base = out["barrier"]
    for k, v in out.items():
        if k != "barrier":
            emit(f"fig3a/measured/{k}_vs_barrier", v,
                 f"speedup={base/v:.2f}x")
    return g, pre


def simulated_scaling(g, pre, n_events: int = 400, seed: int = 0):
    """Critical-path replay with measured cost constants."""
    params = KadabraParams(eps=0.05, delta=0.1, batch=16)
    sample_fn = make_sample_fn(g, pre, params.batch)
    tmpl = frame_template(g)

    # measure S (one sampling round), R per element, C per element
    key = jax.random.key(0)
    s_cost = timeit(lambda: jax.jit(
        lambda k: sample_fn(k, None)[0].data)(key), iters=3)
    n = g.n
    red = jax.jit(lambda x: jnp.sum(x, 0))
    r_cost_4 = timeit(lambda: red(jnp.ones((4, n), jnp.int32)), iters=3)
    from repro.core.stopping import KadabraCondition
    cond = KadabraCondition(eps=0.05, delta=0.1, omega=pre.omega,
                            n_vertices=n)
    from repro.core.frames import StateFrame
    c_cost = timeit(lambda: jax.jit(
        lambda d: cond(StateFrame(num=jnp.int32(100), data=d))[0])(
            jnp.ones((n,), jnp.int32)), iters=3)

    rng = np.random.default_rng(seed)
    K = 4

    def epoch_time(strategy: str, W: int) -> float:
        # iid lognormal round times (graph BFS variance); straggler = max
        rounds = s_cost * rng.lognormal(0.0, 0.25, size=(n_events, W, K))
        s_epoch_max = rounds.sum(2).max(1)    # barrier once per epoch
        R = r_cost_4 / 4 * W                  # linear-in-W accumulation (§3.3)
        C = c_cost
        if strategy == "barrier":
            t = s_epoch_max + R + C
        elif strategy == "local":
            t = np.maximum(s_epoch_max, R) + C
        elif strategy == "shared":
            t = np.maximum(s_epoch_max, R / W * 2) + C / W + 1e-6
        elif strategy == "indexed":
            t = np.maximum(s_epoch_max, R) + min(W, 8) * C  # prefix checks
        elif strategy == "lock":
            # reduce + check after EVERY round, each round barriered
            t = (rounds.max(1) + R + C).sum(1)
        else:
            raise ValueError(strategy)
        return float(np.mean(t))

    # sequential reference: W=1 barrier without reduce
    seq = epoch_time("barrier", 1)
    print("# fig3a simulated parallel speedup (samples/s vs W=1 barrier)")
    header = ["W"] + ["lock", "barrier", "local", "shared", "indexed"]
    print("#", " ".join(f"{h:>8s}" for h in header))
    for W in (1, 2, 4, 8, 16, 32, 64):
        row = [f"{W:>8d}"]
        for strat in header[1:]:
            # throughput = W·K samples per epoch_time; speedup vs seq
            thr = W * 1.0 / epoch_time(strat, W)
            thr_seq = 1.0 / seq
            row.append(f"{thr/thr_seq:8.2f}")
        print("#", " ".join(row))
        if W == 32:
            for strat in ("barrier", "local", "shared", "indexed"):
                thr = W / epoch_time(strat, W) * seq
                emit(f"fig3a/simulated/{strat}/W=32",
                     epoch_time(strat, W), f"speedup={thr:.1f}x")


def paper_platform_model():
    """Replay at the PAPER's scale (36-core Xeon, wikipedia-class graphs):
    n = 3.6e6 vertices, sample = one BFS ≈ 2 ms, frame = 4n bytes,
    thread-0 accumulation R(T) = T·n·4B at ~8 GB/s (§3.3: Θ(T·n)),
    check C = f,g pass over n ≈ 3 ms, memory-bandwidth ceiling on sampling
    beyond ~14 threads (§4: "nearly ideal until 16 cores"), coordinator
    cadence N₀ = N/T^ξ with N=1000, ξ=1.33 (App. C.2/C.3)."""
    import numpy as np
    s1 = 2.0e-3
    n = 3.6e6
    C = 3.0e-3
    r_bw = 8e9
    def R(T):
        return T * n * 4 / r_bw

    def RS(T):                         # reduce-scatter: size-n, not T·n
        return 2 * n * 4 / r_bw

    def straggler(T):
        return 1.0 + 0.18 * np.log2(max(T, 1))

    def bw(T):                         # sampling slowdown
        return 1.0 + max(0.0, (T - 14) / 14) * 0.9

    def epoch(strategy, T):
        N0 = max(1, round(1000 / T ** 1.33))     # samples/thread/epoch
        samp = N0 * s1 * bw(T) * straggler(T)
        if strategy == "lock":                   # original: N=11 cadence,
            k = max(1, round(11 / T))            # lock serializes update+check
            return (k * s1 * bw(T) * straggler(T) + (R(T) + C)) * (N0 / max(k, 1)), N0 * T
        if strategy == "barrier":
            return samp + R(T) + C, N0 * T
        if strategy == "local":
            return max(samp, R(T)) + C, N0 * T
        if strategy == "shared":
            return max(samp, RS(T)) + C / T + 1e-4, N0 * T
        if strategy == "indexed":
            # fixed samples/SF ⇒ stale buffered SFs checked in order: extra
            # C per buffered frame + bandwidth of the gather ≈ local's R
            return max(samp * 1.1, R(T)) + min(T, 8) * C, N0 * T
        raise ValueError(strategy)

    seq_rate = 1.0 / (1000 * s1 + C) * 1000      # samples/s sequential
    print("# fig3a paper-platform model: parallel speedup (samples/s vs seq)")
    print("#        W     lock  barrier    local   shared  indexed")
    for T in (1, 2, 4, 8, 16, 32):
        row = [f"{T:>8d}"]
        for strat in ("lock", "barrier", "local", "shared", "indexed"):
            t, samples = epoch(strat, T)
            row.append(f"{samples / t / seq_rate:8.1f}")
        print("# " + " ".join(row))
        if T == 32:
            for strat in ("barrier", "local", "shared", "indexed"):
                t, samples = epoch(strat, T)
                emit(f"fig3a/paper_model/{strat}/W=32", t,
                     f"speedup={samples / t / seq_rate:.1f}x")


def run() -> None:
    g, pre = measured_overheads()
    simulated_scaling(g, pre)
    paper_platform_model()


if __name__ == "__main__":
    run()

"""ADS instance-layer sweep: wall seconds for every registered workload ×
strategy × world — the multi-workload generalization of the Tables 2–3
KADABRA-only sweep (tables23_instances.py).

    PYTHONPATH=src python -m benchmarks.run --only bench_instances
    PYTHONPATH=src python -m benchmarks.bench_instances [--bench-scale]

CSV: instances/<workload>/<strategy>/W=<w>, us_per_call, tau=<samples>
"""

from __future__ import annotations

import sys

from benchmarks.common import emit, timeit
from repro.core.frames import FrameStrategy
from repro.core.instances import available_instances, run_instance

STRATS = (FrameStrategy.BARRIER, FrameStrategy.LOCAL_FRAME,
          FrameStrategy.SHARED_FRAME, FrameStrategy.INDEXED_FRAME)


def run(bench_scale: bool = False) -> None:
    if bench_scale:
        from repro.configs.adaptive_instances import BENCH
        workloads = list(BENCH.values())
    else:
        workloads = list(available_instances())
    for wl in workloads:
        name = wl if isinstance(wl, str) else wl.name
        for strat in STRATS:
            for world in (1, 4):
                tau = {}

                def once(w=wl, s=strat, ww=world):
                    est, res, _ = run_instance(w, strategy=s, world=ww)
                    tau["v"] = res.num
                    return est

                t = timeit(once, warmup=1, iters=2)
                emit(f"instances/{name}/{strat.value}/W={world}", t,
                     f"tau={tau['v']}")


if __name__ == "__main__":
    run(bench_scale="--bench-scale" in sys.argv[1:])

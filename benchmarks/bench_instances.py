"""ADS instance-layer sweep: wall time for every registered workload ×
strategy × world — the multi-workload generalization of the Tables 2–3
KADABRA-only sweep (tables23_instances.py).

    PYTHONPATH=src python -m benchmarks.run --only bench_instances
    PYTHONPATH=src python -m benchmarks.bench_instances \\
        [--bench-scale] [--out DIR]

The artifact of record is ``<out>/BENCH_instances.json`` (schema in
:mod:`benchmarks.artifact`; validated before writing, re-validated and
uploaded by the CI ``bench-smoke`` job, summarized by
``python -m benchmarks.perf_summary``).  The legacy one-line-per-cell CSV
is still printed so ``benchmarks.run`` keeps forwarding progress rows.

Every timed iteration re-runs the full adaptive loop with a fixed seed, so
the stopped sample count τ must be identical across warmup + timed
iterations — each iteration records ``res.num`` and the sweep fails loudly
if they diverge (timed numbers must never mix differently-sized runs).
"""

from __future__ import annotations

import argparse
from typing import List

from benchmarks.artifact import attach_speedups, write_bench
from benchmarks.common import emit, timeit
from repro.core.frames import FrameStrategy
from repro.core.instances import available_instances, run_instance

STRATS = (FrameStrategy.BARRIER, FrameStrategy.LOCAL_FRAME,
          FrameStrategy.SHARED_FRAME, FrameStrategy.INDEXED_FRAME)
WORLDS = (1, 4)


def run(bench_scale: bool = False, out_dir: str = "bench-artifacts") -> str:
    if bench_scale:
        from repro.configs.adaptive_instances import BENCH
        workloads = list(BENCH.values())
    else:
        workloads = list(available_instances())
    rows: List[dict] = []
    for wl in workloads:
        name = wl if isinstance(wl, str) else wl.name
        for strat in STRATS:
            for world in WORLDS:
                taus: List[int] = []

                def once(w=wl, s=strat, ww=world, taus=taus):
                    est, res, _ = run_instance(w, strategy=s, world=ww)
                    taus.append(int(res.num))
                    return est

                # iters=3: timeit takes ts[len//2], a true median (with 2
                # iterations that picks the max and one hiccup skews every
                # speedup in the cell's group)
                t = timeit(once, warmup=1, iters=3)
                if len(set(taus)) != 1:
                    raise AssertionError(
                        f"{name}/{strat.value}/W={world}: τ varies across "
                        f"iterations {taus} — timing would mix "
                        f"differently-sized runs")
                rows.append({"workload": name, "strategy": strat.value,
                             "world": world, "us_per_call": t * 1e6,
                             "tau": taus[0]})
                emit(f"instances/{name}/{strat.value}/W={world}", t,
                     f"tau={taus[0]}")
    attach_speedups(rows)
    path = write_bench("instances", rows, out_dir=out_dir,
                       scale="bench" if bench_scale else "conformance")
    print(f"# wrote {path}")
    return str(path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-scale", action="store_true",
                    help="use the configs/adaptive_instances.BENCH presets")
    ap.add_argument("--out", default="bench-artifacts",
                    help="directory for BENCH_instances.json")
    args = ap.parse_args()
    run(bench_scale=args.bench_scale, out_dir=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""§Roofline table: aggregates the dry-run JSONs (benchmarks/results/dryrun)
into the per-(arch × shape) three-term table for EXPERIMENTS.md.  No
compilation happens here — run ``benchmarks/run_dryrun_all.sh`` first."""

from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import emit

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def rows(mesh: str = "16x16"):
    out = []
    for f in sorted(glob.glob(str(RESULTS / f"*__{mesh}.json"))):
        if mesh == "16x16" and "2x16x16" in f:
            continue
        out.append(json.load(open(f)))
    return out


def run() -> None:
    n_ok = n_skip = n_err = 0
    for r in rows():
        cell = f"{r['arch']}/{r['shape']}"
        if not r.get("applicable"):
            n_skip += 1
            emit(f"roofline/{cell}", 0.0, "skipped")
            continue
        if "error" in r:
            n_err += 1
            emit(f"roofline/{cell}", 0.0, f"ERROR")
            continue
        n_ok += 1
        t = r.get("roofline", {})
        m = r["memory"]
        dom = t.get("dominant", "?")
        # kernel-path (deploy) memory cross-check — see analysis/analytic.py
        try:
            from repro.analysis.analytic import kernel_memory_s
            from repro.models import SHAPES, get_config
            mem_k = kernel_memory_s(get_config(r["arch"]),
                                    SHAPES[r["shape"]], r.get("chips", 256))
        except Exception:
            mem_k = 0.0
        emit(f"roofline/{cell}",
             max(t.get("compute_s", 0), t.get("memory_s", 0),
                 t.get("collective_s", 0)),
             f"dom={dom};compute_s={t.get('compute_s', 0):.4f};"
             f"memory_s={t.get('memory_s', 0):.4f};"
             f"mem_s_kernel={mem_k:.4f};"
             f"collective_s={t.get('collective_s', 0):.4f};"
             f"useful={t.get('useful_ratio', 0):.2f};"
             f"peak_GB={m['peak_bytes']/2**30:.1f}")
    print(f"# roofline table: {n_ok} cells, {n_skip} skips, {n_err} errors")


if __name__ == "__main__":
    run()

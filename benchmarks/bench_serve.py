"""Serving-subsystem throughput/latency sweep: run the epoch-granular
scheduler (:mod:`repro.serve.scheduler`) over a mixed query stream and emit
the ``BENCH_serve.json`` perf artifact (``kind="serve"`` schema in
:mod:`benchmarks.artifact`).

    PYTHONPATH=src python -m benchmarks.bench_serve \\
        [--max-in-flight 3] [--queries SPEC[,SPEC...]] [--out DIR]

Each SPEC is ``instance:strategy:world[:seed]``; the default stream mixes
three workloads across strategies and worker counts — small enough for the
CI ``serve-smoke`` job, heterogeneous enough that continuous batching at
epoch granularity is actually exercised (queries retire at different
ticks and queued queries are admitted into freed slots).

Per-query τ is a pure function of (instance, strategy, world, seed), so the
artifact rows are deterministic modulo wall time — exactly what
``benchmarks.artifact diff`` needs: τ changes are semantic regressions,
``us_per_call`` moves inside a tolerance band.
"""

from __future__ import annotations

import argparse
from typing import List, Sequence

from benchmarks.artifact import write_bench
from benchmarks.common import emit
from repro.serve import EpochScheduler, SessionSpec

DEFAULT_QUERIES = (
    "reachability:local:2:0",
    "triangles:barrier:1:1",
    "wrs:shared:4:2",
    "reachability:indexed:4:3",
    "triangles:local:4:4",
    "wrs:local:2:5",
)


def run(queries: Sequence[str] = DEFAULT_QUERIES, *,
        max_in_flight: int = 3, substrate: "str | None" = None,
        out_dir: str = "bench-artifacts") -> str:
    sched = EpochScheduler(max_in_flight=max_in_flight, substrate=substrate)
    for q in queries:
        sched.submit(SessionSpec.parse(q))
    sched.drain()

    rows: List[dict] = []
    for qid, r in sorted(sched.results.items()):
        rows.append({"query": qid, "workload": r.spec.instance,
                     "strategy": r.spec.strategy, "world": r.spec.world,
                     "us_per_call": r.wall_s * 1e6, "tau": r.tau,
                     "epochs": r.epochs, "wait_ticks": r.wait_ticks})
        emit(f"serve/{qid}", r.wall_s,
             f"tau={r.tau} epochs={r.epochs} wait={r.wait_ticks}")
    path = write_bench("serve", rows, out_dir=out_dir, kind="serve")
    print(f"# wrote {path} ({len(rows)} queries, "
          f"{sched.tick_count} scheduler ticks, "
          f"{len(sched.cache)} compiled steppers)")
    return str(path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default=",".join(DEFAULT_QUERIES),
                    help="comma-separated instance:strategy:world[:seed]")
    ap.add_argument("--max-in-flight", type=int, default=3)
    ap.add_argument("--substrate", default=None,
                    help="force a substrate for every query "
                         "(sequential|vmap|shard_map)")
    ap.add_argument("--out", default="bench-artifacts",
                    help="directory for BENCH_serve.json")
    args = ap.parse_args()
    run([q for q in args.queries.split(",") if q],
        max_in_flight=args.max_in_flight, substrate=args.substrate,
        out_dir=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

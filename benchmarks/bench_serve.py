"""Serving-subsystem throughput/latency sweep: run the epoch-granular
scheduler (:mod:`repro.serve.scheduler`) over a mixed query stream and emit
the ``BENCH_serve.json`` perf artifact (``kind="serve"`` schema in
:mod:`benchmarks.artifact`).

    PYTHONPATH=src python -m benchmarks.bench_serve \\
        [--max-in-flight 3] [--queries SPEC[,SPEC...]] [--out DIR] \\
        [--topology auto|N|GxN] [--pressure-policy shrink[-regrow][:min=N]]

Each SPEC is ``instance:strategy:world[:seed]``; the default stream mixes
three workloads across strategies and worker counts — small enough for the
CI ``serve-smoke`` job, heterogeneous enough that continuous batching at
epoch granularity is actually exercised (queries retire at different
ticks and queued queries are admitted into freed slots).

``--topology`` attaches a placement pool (:mod:`repro.serve.placement`):
each admitted query leases a pairwise-disjoint submesh, rows gain real
``devices_leased`` / ``placement_wait_ticks`` numbers, and
``--pressure-policy`` lets the scheduler resize SHARED_FRAME sessions under
queued load — the CI ``serve-placement`` job runs exactly that under
forced-8-device XLA flags.

Per-query τ is a pure function of (instance, strategy, world, seed), so the
artifact rows are deterministic modulo wall time — exactly what
``benchmarks.artifact diff`` needs: τ changes are semantic regressions,
``us_per_call`` moves inside a tolerance band.  (Pressure-driven reshards
preserve τ bit-for-bit, so rows stay deterministic even under a pool.)
"""

from __future__ import annotations

import argparse
from typing import List, Optional, Sequence

from benchmarks.artifact import write_bench
from benchmarks.common import emit
from repro.serve import EpochScheduler, PressurePolicy, SessionSpec

DEFAULT_QUERIES = (
    "reachability:local:2:0",
    "triangles:barrier:1:1",
    "wrs:shared:4:2",
    "reachability:indexed:4:3",
    "triangles:local:4:4",
    "wrs:local:2:5",
)


def run(queries: Sequence[str] = DEFAULT_QUERIES, *,
        max_in_flight: int = 3, substrate: "str | None" = None,
        topology: "str | None" = None,
        pressure_policy: str = "none",
        out_dir: str = "bench-artifacts") -> str:
    pool = None
    if topology:
        from repro.launch.mesh import make_device_pool
        pool = make_device_pool(topology)
    pressure: Optional[PressurePolicy] = PressurePolicy.parse(pressure_policy)
    sched = EpochScheduler(max_in_flight=max_in_flight, substrate=substrate,
                           pool=pool, pressure=pressure)
    for q in queries:
        sched.submit(SessionSpec.parse(q))
    for ev in sched.drain():
        for qid, old_w, new_w in ev.resharded:
            emit(f"serve/reshard/{qid}", 0.0, f"W={old_w} -> {new_w}")

    rows: List[dict] = []
    for qid, r in sorted(sched.results.items()):
        rows.append({"query": qid, "workload": r.spec.instance,
                     "strategy": r.spec.strategy, "world": r.spec.world,
                     "us_per_call": r.wall_s * 1e6, "tau": r.tau,
                     "epochs": r.epochs, "wait_ticks": r.wait_ticks,
                     "devices_leased": r.devices_leased,
                     "placement_wait_ticks": r.placement_wait_ticks})
        emit(f"serve/{qid}", r.wall_s,
             f"tau={r.tau} epochs={r.epochs} wait={r.wait_ticks} "
             f"dev={r.devices_leased} pwait={r.placement_wait_ticks}")
    path = write_bench("serve", rows, out_dir=out_dir, kind="serve",
                       pool_devices=pool.capacity if pool else None)
    print(f"# wrote {path} ({len(rows)} queries, "
          f"{sched.tick_count} scheduler ticks, "
          f"{len(sched.cache)} compiled steppers"
          + (f", pool of {pool.capacity}" if pool else "") + ")")
    return str(path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default=",".join(DEFAULT_QUERIES),
                    help="comma-separated instance:strategy:world[:seed]")
    ap.add_argument("--max-in-flight", type=int, default=3)
    ap.add_argument("--substrate", default=None,
                    help="force a substrate for every query "
                         "(sequential|vmap|shard_map)")
    ap.add_argument("--topology", default="",
                    help="attach a placement pool: 'auto' | 'N' | 'GxN' "
                         "(empty = no pool)")
    ap.add_argument("--pressure-policy", default="none",
                    help="none | shrink | shrink-regrow[:min=N]")
    ap.add_argument("--out", default="bench-artifacts",
                    help="directory for BENCH_serve.json")
    args = ap.parse_args()
    if PressurePolicy.parse(args.pressure_policy) is not None \
            and not args.topology:
        ap.error("--pressure-policy needs --topology (a device pool)")
    run([q for q in args.queries.split(",") if q],
        max_in_flight=args.max_in_flight, substrate=args.substrate,
        topology=args.topology, pressure_policy=args.pressure_policy,
        out_dir=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

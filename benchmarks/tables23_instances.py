"""Tables 2–3 analog: absolute ADS runtimes per instance × strategy.

The paper reports per-instance absolute seconds for OMP/L/S/I at 1–32
cores; we report wall seconds for the four strategies at W ∈ {1, 4} virtual
workers on the synthetic instance set (categories matched to App. E)."""

from __future__ import annotations

from benchmarks.common import emit, instances, timeit
from repro.core.frames import FrameStrategy
from repro.graphs import KadabraParams, preprocess, run_kadabra

STRATS = {
    "OMP": FrameStrategy.BARRIER,
    "L": FrameStrategy.LOCAL_FRAME,
    "S": FrameStrategy.SHARED_FRAME,
    "I": FrameStrategy.INDEXED_FRAME,
}


def run() -> None:
    for name, make in instances().items():
        if name.endswith("-m"):
            continue  # keep the sweep fast; -m covered in fig2 benches
        g = make()
        pre = preprocess(g, eps=0.05, delta=0.1)
        params = KadabraParams(eps=0.05, delta=0.1, batch=16,
                               rounds_per_epoch=4, max_epochs=3000)
        for label, strat in STRATS.items():
            for world in (1, 4):
                if strat == FrameStrategy.SHARED_FRAME and world == 1:
                    continue
                t = timeit(lambda s=strat, w=world: run_kadabra(
                    g, params, strategy=s, world=w, pre=pre)[0],
                    warmup=1, iters=2)
                emit(f"tables23/{name}/{label}/W={world}", t, "")


if __name__ == "__main__":
    run()

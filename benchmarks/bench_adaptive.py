"""Framework-side benchmark: adaptive vs fixed gradient accumulation
(the paper's technique applied to training, DESIGN.md §3.1).

Derived metric: fraction of microbatches saved at equal optimizer-visible
gradient quality target."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.models import Model
from repro.optim import AdaptiveAccumConfig, adaptive_accumulate


def run() -> None:
    import repro.configs.smollm_360m as sm
    cfg = sm.reduced()
    model = Model(cfg, None)
    params = model.init(jax.random.key(0))
    from repro.data import TokenStream
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, batch=16, seed=0)
    micro = jax.tree.map(lambda x: x.reshape((8, 2) + x.shape[1:]),
                         stream.batch_at(jnp.int32(0)))

    def grad_fn(p, b):
        return jax.value_and_grad(model.train_loss)(p, b)

    acc = AdaptiveAccumConfig(rtol=0.2, min_micro=2, max_micro=8)
    run_fn = jax.jit(lambda p, m: adaptive_accumulate(grad_fn, p, m, acc)[2])
    n_used = int(run_fn(params, micro))
    t = timeit(lambda: run_fn(params, micro), warmup=1, iters=2)
    emit("adaptive_accum/micro_used", t,
         f"used={n_used}/8;saved={100*(8-n_used)/8:.0f}%")


if __name__ == "__main__":
    run()

"""Machine-readable perf artifacts: ``BENCH_<suite>.json`` writer + validator.

This is the repo's perf-trajectory format — what CI records, uploads, and
regresses against (the Tables 2–3 speedup-vs-strategy reproduction needs
structured numbers, not ad-hoc CSV).  The schema is hand-validated (no
jsonschema dependency in the container):

Envelope (one file per benchmark suite)::

    {
      "schema_version": 2,
      "suite": "instances",            # BENCH_<suite>.json
      "kind": "instances",             # row schema: "instances" | "serve"
      "jax_version": "0.4.37",
      "platform": "cpu",               # jax.default_backend()
      "created_unix": 1753776000.0,
      "scale": "conformance",          # or "bench" (--bench-scale)
      "rows": [ <row>, ... ]           # non-empty
    }

``kind`` selects the row schema and the diff join key; artifacts written
before the field existed validate as ``kind="instances"`` (the default), so
old uploads stay readable and diffable.  ``schema_version`` 1 artifacts
also stay valid: version 2 (placement-aware serving) adds the
``devices_leased`` / ``placement_wait_ticks`` serve-row fields, which are
required at version 2 and optional (defaulting to 0) at version 1.

Row, ``kind="instances"`` (one measured strategy×W cell)::

    {
      "workload": "kadabra",           # registered instance name
      "strategy": "local",             # FrameStrategy value
      "world": 4,                      # (virtual) worker count, ≥ 1
      "us_per_call": 1234.5,           # median wall time, > 0
      "tau": 4096,                     # final sample count, > 0
      "speedup_vs_barrier": 1.8        # us(BARRIER @ same workload+W)/us;
    }                                  # 1.0 on BARRIER rows; null if no
                                       # BARRIER row exists for the cell

Row, ``kind="serve"`` (one retired scheduler query)::

    {
      "query": "q000-kadabra",         # unique query id (the join key)
      "workload": "kadabra",
      "strategy": "local",
      "world": 4,                      # FINAL world (pressure may resize)
      "us_per_call": 250000.0,         # host wall time stepping it, > 0
      "tau": 4096,                     # final sample count, > 0
      "epochs": 12,                    # epochs to retirement, ≥ 1
      "wait_ticks": 3,                 # ticks queued before admission, ≥ 0
      "devices_leased": 4,             # peak lease width, ≥ 0 (0: no pool)
      "placement_wait_ticks": 1        # ticks queued on a full pool, ≥ 0
    }

Usage::

    python -m benchmarks.artifact validate out/BENCH_*.json
    python -m benchmarks.artifact diff OLD.json NEW.json [--rtol 0.25]

``diff`` is the regression gate: it joins two artifacts on
(workload, strategy, world) — or on the query id for ``kind="serve"`` —
applies a tolerance band (relative ``--rtol`` plus an absolute ``--min-us``
floor below which CPU timing noise dominates), and exits non-zero on
regressions, τ changes, or rows that disappeared — CI runs it
``continue-on-error`` as a report; locally it is a real gate.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

SCHEMA_VERSION = 2
# older artifacts that remain readable/diffable (the v2 additions are
# serve-row placement fields, absent-means-0 when reading v1)
_READABLE_VERSIONS = (1, SCHEMA_VERSION)

_ENVELOPE_FIELDS = {
    "schema_version": int,
    "suite": str,
    "jax_version": str,
    "platform": str,
    "created_unix": (int, float),
    "scale": str,
    "rows": list,
}

_ROW_FIELDS = {
    "workload": str,
    "strategy": str,
    "world": int,
    "us_per_call": (int, float),
    "tau": int,
    "speedup_vs_barrier": (int, float, type(None)),
}

_ROW_FIELDS_SERVE = {
    "query": str,
    "workload": str,
    "strategy": str,
    "world": int,
    "us_per_call": (int, float),
    "tau": int,
    "epochs": int,
    "wait_ticks": int,
}

# placement columns: required at schema_version 2, optional (0) at 1
_ROW_FIELDS_SERVE_V2 = {
    "devices_leased": int,
    "placement_wait_ticks": int,
}

_STRATEGIES = ("lock", "barrier", "local", "shared", "indexed")
_SCALES = ("conformance", "bench")
_KINDS = ("instances", "serve")


def doc_kind(doc: Dict[str, Any]) -> str:
    """Row-schema kind; pre-``kind`` artifacts default to ``instances``."""
    return doc.get("kind", "instances")


def validate_bench(doc: Dict[str, Any]) -> List[str]:
    """Structural + semantic validation; returns a list of error strings."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"document is {type(doc).__name__}, expected object"]
    for key, typ in _ENVELOPE_FIELDS.items():
        if key not in doc:
            errs.append(f"missing envelope field {key!r}")
        elif not isinstance(doc[key], typ) or isinstance(doc[key], bool):
            errs.append(f"envelope field {key!r} has type "
                        f"{type(doc[key]).__name__}")
    if errs:
        return errs
    if doc["schema_version"] not in _READABLE_VERSIONS:
        errs.append(f"schema_version {doc['schema_version']} not in "
                    f"{_READABLE_VERSIONS}")
    if doc["scale"] not in _SCALES:
        errs.append(f"scale {doc['scale']!r} not in {_SCALES}")
    kind = doc_kind(doc)
    if not isinstance(kind, str) or kind not in _KINDS:
        errs.append(f"kind {kind!r} not in {_KINDS}")
        return errs
    serve = kind == "serve"
    row_fields = dict(_ROW_FIELDS_SERVE) if serve else _ROW_FIELDS
    if serve and doc["schema_version"] >= 2:
        row_fields.update(_ROW_FIELDS_SERVE_V2)  # required from v2 on
    if not doc["rows"]:
        errs.append("rows is empty")
    barrier_us: Dict[tuple, float] = {}
    seen_queries: Dict[str, int] = {}
    for i, row in enumerate(doc["rows"]):
        where = f"rows[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where}: not an object")
            continue
        for key, typ in row_fields.items():
            if key not in row:
                errs.append(f"{where}: missing field {key!r}")
            elif not isinstance(row[key], typ) or isinstance(row[key], bool):
                errs.append(f"{where}.{key}: type {type(row[key]).__name__}")
        if any(e.startswith((where + ":", where + ".")) for e in errs):
            continue
        if row["strategy"] not in _STRATEGIES:
            errs.append(f"{where}: strategy {row['strategy']!r} not in "
                        f"{_STRATEGIES}")
        if row["world"] < 1:
            errs.append(f"{where}: world {row['world']} < 1")
        if row["us_per_call"] <= 0:
            errs.append(f"{where}: us_per_call {row['us_per_call']} <= 0")
        if row["tau"] <= 0:
            errs.append(f"{where}: tau {row['tau']} <= 0")
        if serve:
            if row["epochs"] < 1:
                errs.append(f"{where}: epochs {row['epochs']} < 1")
            if row["wait_ticks"] < 0:
                errs.append(f"{where}: wait_ticks {row['wait_ticks']} < 0")
            # placement fields: required at v2 (row_fields), optional at
            # v1 — but never negative, and never mistyped, when present
            for key in _ROW_FIELDS_SERVE_V2:
                val = row.get(key, 0)
                if isinstance(val, bool) or not isinstance(val, int):
                    errs.append(f"{where}.{key}: type "
                                f"{type(val).__name__}")
                elif val < 0:
                    errs.append(f"{where}: {key} {val} < 0")
            if row["query"] in seen_queries:
                errs.append(f"{where}: duplicate query id {row['query']!r} "
                            f"(also rows[{seen_queries[row['query']]}])")
            seen_queries[row["query"]] = i
            continue
        sp = row["speedup_vs_barrier"]
        if sp is not None and sp <= 0:
            errs.append(f"{where}: speedup_vs_barrier {sp} <= 0")
        if row["strategy"] == "barrier":
            barrier_us[(row["workload"], row["world"])] = row["us_per_call"]
    if serve:
        return errs
    # cells with a BARRIER baseline must carry a speedup (and vice versa)
    for i, row in enumerate(doc["rows"]):
        if not isinstance(row, dict) or "workload" not in row:
            continue
        has_base = (row.get("workload"), row.get("world")) in barrier_us
        sp = row.get("speedup_vs_barrier")
        if has_base and sp is None:
            errs.append(f"rows[{i}]: BARRIER baseline exists but "
                        f"speedup_vs_barrier is null")
        if not has_base and sp is not None:
            errs.append(f"rows[{i}]: speedup_vs_barrier set without a "
                        f"BARRIER baseline row")
    return errs


def attach_speedups(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Fill ``speedup_vs_barrier`` from the BARRIER rows in ``rows``."""
    base = {(r["workload"], r["world"]): r["us_per_call"]
            for r in rows if r["strategy"] == "barrier"}
    for r in rows:
        us = base.get((r["workload"], r["world"]))
        r["speedup_vs_barrier"] = None if us is None \
            else round(us / r["us_per_call"], 4)
    return rows


def write_bench(suite: str, rows: Sequence[Dict[str, Any]], *,
                out_dir: "str | Path" = "bench-artifacts",
                scale: str = "conformance",
                kind: str = "instances",
                pool_devices: Optional[int] = None) -> Path:
    """Validate and write ``BENCH_<suite>.json``; returns the path.

    ``pool_devices`` (serve runs with a placement pool) records the pool
    capacity in the envelope so the summary can print device utilization —
    optional, and ignored by the validator when absent."""
    import jax

    doc = {
        "schema_version": SCHEMA_VERSION,
        "suite": suite,
        "kind": kind,
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "created_unix": time.time(),
        "scale": scale,
        "rows": list(rows),
    }
    if pool_devices is not None:
        doc["pool_devices"] = pool_devices
    errs = validate_bench(doc)
    if errs:
        raise ValueError("refusing to write invalid BENCH artifact:\n  "
                         + "\n  ".join(errs))
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"BENCH_{suite}.json"
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path


def load_bench(path: "str | Path") -> Dict[str, Any]:
    """Load + validate one artifact; raises ValueError on schema errors."""
    doc = json.loads(Path(path).read_text())
    errs = validate_bench(doc)
    if errs:
        raise ValueError(f"{path}: invalid BENCH artifact:\n  "
                         + "\n  ".join(errs))
    return doc


# ---------------------------------------------------------------------------
# Artifact diff — the regression gate between two BENCH_*.json files.
# ---------------------------------------------------------------------------

def _row_key(row: Dict[str, Any], kind: str = "instances") -> tuple:
    if kind == "serve":
        return (row["query"],)
    return (row["workload"], row["strategy"], row["world"])


def diff_bench(old: Dict[str, Any], new: Dict[str, Any], *,
               rtol: float = 0.25, min_us: float = 50.0) -> Dict[str, Any]:
    """Compare two validated artifacts row-by-row with tolerance bands.

    Rows join on (workload, strategy, world) for ``kind="instances"`` and
    on the query id for ``kind="serve"`` (both artifacts must be the same
    kind).  A cell regresses when its ``us_per_call`` grows by more than
    ``rtol`` relative *and* more than ``min_us`` absolute
    (conformance-scale CPU numbers are compile-dominated; sub-``min_us``
    jitter is not signal).  τ differences are always failures — the
    adaptive loop stopped at a different sample count, i.e. the semantics
    changed, so the timing comparison is void.  Rows present in ``old`` but
    missing from ``new`` fail too (a silently dropped cell is not a pass);
    rows new in ``new`` are reported but never fail.

    Returns a report dict::

        {"ok": bool, "regressions": [...], "improvements": [...],
         "tau_changes": [...], "missing": [...], "added": [...],
         "unchanged": int, "lines": [human-readable per-finding strings]}
    """
    if not 0 <= rtol:
        raise ValueError(f"rtol must be >= 0, got {rtol}")
    kind = doc_kind(old)
    if doc_kind(new) != kind:
        raise ValueError(f"cannot diff kind={kind!r} against "
                         f"kind={doc_kind(new)!r}")
    old_rows = {_row_key(r, kind): r for r in old["rows"]}
    new_rows = {_row_key(r, kind): r for r in new["rows"]}
    rep: Dict[str, Any] = {"regressions": [], "improvements": [],
                           "tau_changes": [], "missing": [], "added": [],
                           "unchanged": 0, "lines": []}

    def name(k):
        return k[0] if kind == "serve" else f"{k[0]}/{k[1]}/W={k[2]}"

    for key in sorted(old_rows):
        if key not in new_rows:
            rep["missing"].append(name(key))
            rep["lines"].append(f"MISSING  {name(key)}: row dropped from "
                                f"new artifact")
    for key in sorted(new_rows):
        if key not in old_rows:
            rep["added"].append(name(key))
            rep["lines"].append(f"new      {name(key)}: "
                                f"{new_rows[key]['us_per_call']:.1f}us")
    for key in sorted(set(old_rows) & set(new_rows)):
        o, n = old_rows[key], new_rows[key]
        if o["tau"] != n["tau"]:
            rep["tau_changes"].append(name(key))
            rep["lines"].append(f"TAU      {name(key)}: {o['tau']} -> "
                               f"{n['tau']} (semantics changed)")
            continue
        ratio = n["us_per_call"] / o["us_per_call"]
        delta = n["us_per_call"] - o["us_per_call"]
        if ratio > 1.0 + rtol and delta > min_us:
            rep["regressions"].append(name(key))
            rep["lines"].append(
                f"REGRESS  {name(key)}: {o['us_per_call']:.1f}us -> "
                f"{n['us_per_call']:.1f}us ({ratio:.2f}x, band "
                f"rtol={rtol} min_us={min_us})")
        elif ratio < 1.0 - rtol and -delta > min_us:
            rep["improvements"].append(name(key))
            rep["lines"].append(
                f"improve  {name(key)}: {o['us_per_call']:.1f}us -> "
                f"{n['us_per_call']:.1f}us ({ratio:.2f}x)")
        else:
            rep["unchanged"] += 1
    rep["ok"] = not (rep["regressions"] or rep["tau_changes"]
                     or rep["missing"])
    return rep


def _cli_validate(files: Sequence[str]) -> int:
    bad = 0
    for name in files:
        try:
            doc = load_bench(name)
        except (ValueError, OSError, json.JSONDecodeError) as e:
            print(f"FAIL {name}: {e}", file=sys.stderr)
            bad += 1
        else:
            print(f"ok   {name}: suite={doc['suite']} kind={doc_kind(doc)} "
                  f"rows={len(doc['rows'])} scale={doc['scale']} "
                  f"jax={doc['jax_version']}/{doc['platform']}")
    return 1 if bad else 0


def _cli_diff(argv: Sequence[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.artifact diff",
        description="regression-gate two BENCH_*.json artifacts")
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="relative tolerance band (default 0.25)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="ignore absolute deltas below this (default 50us)")
    args = ap.parse_args(list(argv))
    try:
        old, new = load_bench(args.old), load_bench(args.new)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 2
    rep = diff_bench(old, new, rtol=args.rtol, min_us=args.min_us)
    for line in rep["lines"]:
        print(line)
    print(f"diff {args.old} -> {args.new}: "
          f"{len(rep['regressions'])} regressions, "
          f"{len(rep['tau_changes'])} tau changes, "
          f"{len(rep['missing'])} missing, {len(rep['added'])} new, "
          f"{len(rep['improvements'])} improvements, "
          f"{rep['unchanged']} within band")
    return 0 if rep["ok"] else 1


def _cli(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "validate" and len(argv) >= 2:
        return _cli_validate(argv[1:])
    if argv and argv[0] == "diff" and len(argv) >= 3:
        return _cli_diff(argv[1:])
    print("usage: python -m benchmarks.artifact validate FILE...\n"
          "       python -m benchmarks.artifact diff OLD NEW "
          "[--rtol R] [--min-us U]", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(_cli())

"""Repo-level perf summary over ``BENCH_*.json`` artifacts.

    python -m benchmarks.perf_summary [PATH ...]

PATH entries are artifact files or directories to scan (default:
``bench-artifacts``).  Every artifact is schema-validated on load; the
summary prints one speedup-vs-BARRIER table per suite — the repo's
Tables 2–3 analog over live data — plus a per-workload best-strategy line.
Exit code is non-zero on missing/invalid artifacts, so CI can gate on it.
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import Dict, List, Sequence

from benchmarks.artifact import load_bench


def _collect(paths: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for p in (paths or ["bench-artifacts"]):
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.glob("BENCH_*.json")))
        else:
            files.append(path)
    return files


def summarize(doc: Dict) -> str:
    from benchmarks.artifact import doc_kind
    if doc_kind(doc) == "serve":
        return summarize_serve(doc)
    lines = [f"## suite={doc['suite']} scale={doc['scale']} "
             f"jax={doc['jax_version']} platform={doc['platform']}",
             f"{'workload':<16} {'strategy':<8} {'W':>2} "
             f"{'us/call':>12} {'tau':>8} {'speedup':>8}"]
    best: Dict[str, tuple] = {}
    for r in sorted(doc["rows"], key=lambda r: (r["workload"], r["world"],
                                                r["strategy"])):
        sp = r["speedup_vs_barrier"]
        lines.append(f"{r['workload']:<16} {r['strategy']:<8} "
                     f"{r['world']:>2} {r['us_per_call']:>12.1f} "
                     f"{r['tau']:>8} "
                     + (f"{sp:>8.2f}" if sp is not None else f"{'-':>8}"))
        if sp is not None:
            cur = best.get(r["workload"])
            if cur is None or sp > cur[0]:
                best[r["workload"]] = (sp, r["strategy"], r["world"])
    for wl, (sp, strat, w) in sorted(best.items()):
        lines.append(f"# best[{wl}]: {strat} W={w} at {sp:.2f}x vs barrier")
    return "\n".join(lines)


def summarize_serve(doc: Dict) -> str:
    """Per-query latency table + pool aggregates for ``kind="serve"``,
    plus a device-utilization table when the rows carry placement data."""
    lines = [f"## suite={doc['suite']} kind=serve scale={doc['scale']} "
             f"jax={doc['jax_version']} platform={doc['platform']}",
             f"{'query':<24} {'strategy':<8} {'W':>2} {'epochs':>6} "
             f"{'tau':>8} {'wait':>5} {'dev':>4} {'pwait':>5} "
             f"{'wall_ms':>10}"]
    total_wall = 0.0
    total_tau = 0
    waits = []
    placed = []                      # (query, devices_leased, epochs)
    for r in sorted(doc["rows"], key=lambda r: r["query"]):
        wall_ms = r["us_per_call"] / 1e3
        total_wall += wall_ms
        total_tau += r["tau"]
        waits.append(r["wait_ticks"])
        dev = r.get("devices_leased", 0)
        pwait = r.get("placement_wait_ticks", 0)
        if dev:
            placed.append((r["query"], dev, r["epochs"]))
        lines.append(f"{r['query']:<24} {r['strategy']:<8} {r['world']:>2} "
                     f"{r['epochs']:>6} {r['tau']:>8} {r['wait_ticks']:>5} "
                     f"{dev:>4} {pwait:>5} {wall_ms:>10.1f}")
    n = len(doc["rows"])
    lines.append(f"# pool: {n} queries, {total_tau} samples, "
                 f"{total_wall:.1f}ms stepping wall, "
                 f"mean wait {sum(waits)/max(n,1):.1f} ticks, "
                 f"{total_tau/max(total_wall/1e3,1e-9):.0f} samples/s")
    if placed:
        # devices_leased records the PEAK lease width, so dev×epochs is an
        # upper bound on true occupancy for sessions the pressure policy
        # resized mid-stream (exact integrals would need per-tick widths).
        lines.append("")
        lines.append(f"{'device utilization (peak)':<25} {'dev':>4} "
                     f"{'epochs':>6} {'dev-epochs':>10} {'share':>7}")
        total_de = sum(d * e for _, d, e in placed)
        for q, d, e in placed:
            share = d * e / max(total_de, 1)
            lines.append(f"{q:<25} {d:>4} {e:>6} {d * e:>10} "
                         f"{share:>6.0%}")
        cap = doc.get("pool_devices")
        mean_w = total_de / max(sum(e for _, _, e in placed), 1)
        tail = (f"# ≤ {total_de} device-epochs over {len(placed)} placed "
                f"queries, mean peak lease width {mean_w:.1f}")
        if isinstance(cap, int) and cap > 0:
            tail += f" of a {cap}-device pool (≤ {mean_w / cap:.0%})"
        lines.append(tail)
    return "\n".join(lines)


def main(argv: Sequence[str] = ()) -> int:
    files = _collect(list(argv) or sys.argv[1:])
    if not files:
        print("no BENCH_*.json artifacts found", file=sys.stderr)
        return 1
    bad = 0
    for f in files:
        try:
            doc = load_bench(f)
        except (ValueError, OSError) as e:
            print(f"FAIL {f}: {e}", file=sys.stderr)
            bad += 1
            continue
        print(summarize(doc))
        print()
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())

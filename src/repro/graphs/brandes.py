"""Exact betweenness centrality (Brandes 2001) — pure-numpy test oracle.

Normalized by n(n−1) over ordered pairs, matching KADABRA's estimator
b(v) = (1/(n(n−1))) Σ_{s≠t} σ_st(v)/σ_st  (paper §2.2/§2.3).
"""

from __future__ import annotations

from collections import deque

import numpy as np

from .csr import Graph


def brandes_exact(g: Graph) -> np.ndarray:
    n = g.n
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices_padded)[: g.m_arcs]
    bc = np.zeros(n, dtype=np.float64)
    for s in range(n):
        dist = np.full(n, -1, dtype=np.int64)
        sigma = np.zeros(n, dtype=np.float64)
        dist[s] = 0
        sigma[s] = 1.0
        order = []
        q = deque([s])
        while q:
            v = q.popleft()
            order.append(v)
            for w in indices[indptr[v]:indptr[v + 1]]:
                if dist[w] < 0:
                    dist[w] = dist[v] + 1
                    q.append(w)
                if dist[w] == dist[v] + 1:
                    sigma[w] += sigma[v]
        delta = np.zeros(n, dtype=np.float64)
        for w in reversed(order):
            for u in indices[indptr[w]:indptr[w + 1]]:
                if dist[u] == dist[w] - 1 and sigma[w] > 0:
                    delta[u] += sigma[u] / sigma[w] * (1.0 + delta[w])
            if w != s:
                bc[w] += delta[w]
    # Brandes accumulates over ordered (s, t≠s) pairs already (dependency
    # accumulation counts each target t once per source s).
    return bc / (n * (n - 1))

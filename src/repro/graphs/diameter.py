"""Graph-diameter estimation via double-sweep BFS — an ADS workload on the
epoch engine.

One sample picks a vertex v uniformly, runs a BFS sweep to get ecc(v) and
the farthest vertex u = argmax dist(v,·), then a second sweep from u for
ecc(u) (the classic double-sweep lower bound; Magnien–Latapy–Habib).  Both
sweeps reuse the level-synchronous frontier expansion of
:mod:`repro.graphs.bfs` — i.e. the same hot loop the
``kernels/bfs_frontier`` Pallas kernel serves on TPU.  Every sample yields

    lower bound   ecc(u)      ≤ diam
    upper bound   2·ecc(v)    ≥ diam      (triangle inequality)

and a *gap certificate* when 2·ecc(v) − ecc(u) ≤ gap: the best lower bound
seen is then within ``gap`` of the true diameter.  Sampling adapts to the
graph: one sweep from a near-central vertex certifies immediately, while
hard instances keep sampling until the static cap.

Frame layout (all-integer ⇒ exact reductions, INDEXED bit-identity free):

    frame.num  — number of double sweeps
    frame.data — {"cert": int32 scalar — number of gap certificates,
                  "ecc_hist": (L_pad,) int32 — histogram of observed ecc(u)
                  values (L = n+1 bins; a vector leaf so SHARED_FRAME
                  exercises a real reduce-scatter)}

The estimate max{d : ecc_hist[d] > 0} is sum-recoverable — the frame monoid
is elementwise ``+``, so a max-of-samples statistic must be carried as an
occupancy histogram, not a scalar.  Stopping rule:
:class:`~repro.core.stopping.EccentricityGapCondition` (scalar-only verdict
⇒ shard-safe).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.frames import StateFrame
from .bfs import INF, bfs_sssp
from .csr import Graph


def diameter_exact(g: Graph) -> int:
    """Exact diameter by BFS from every vertex (numpy, test oracle).

    Unreachable pairs are ignored (diameter of the largest-distance
    connected pair), matching what double sweeps can observe.
    """
    n = g.n
    indptr = np.asarray(g.indptr)
    # strip the sentinel tail; keep only real neighbor slots
    nbrs = np.asarray(g.indices_padded)[: int(g.m_arcs)]
    best = 0
    for s in range(n):
        dist = np.full(n, -1, np.int64)
        dist[s] = 0
        frontier = [s]
        while frontier:
            nxt = []
            for v in frontier:
                for w in nbrs[indptr[v]:indptr[v + 1]]:
                    if dist[w] < 0:
                        dist[w] = dist[v] + 1
                        nxt.append(int(w))
            frontier = nxt
        best = max(best, int(dist.max()))
    return best


def double_sweep(g: Graph, v: jax.Array, *, max_levels: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """One double sweep from v → (ecc(v), ecc(u)) with u = argmax dist(v,·)."""
    dist_v, _ = bfs_sssp(g, v, None, max_levels=max_levels, early_exit=False)
    fin_v = jnp.where(dist_v == INF, -1, dist_v)
    u = jnp.argmax(fin_v).astype(jnp.int32)
    ecc_v = jnp.maximum(jnp.max(fin_v), 0)
    dist_u, _ = bfs_sssp(g, u, None, max_levels=max_levels, early_exit=False)
    ecc_u = jnp.max(jnp.where(dist_u == INF, 0, dist_u))
    return ecc_v, ecc_u


def make_sweep_sample_fn(g: Graph, batch: int, *, gap: int = 0,
                         pad_to: Optional[int] = None):
    """Build SAMPLE() — one vectorized round of ``batch`` double sweeps."""
    n = g.n
    bins = n + 1              # ecc ∈ [0, n−1]; bin d counts sweeps with ecc(u)=d
    bins_pad = pad_to or bins
    max_levels = n            # each BFS exits when its frontier empties

    def one(key: jax.Array) -> Tuple[jax.Array, jax.Array]:
        v = jax.random.randint(key, (), 0, n, dtype=jnp.int32)
        ecc_v, ecc_u = double_sweep(g, v, max_levels=max_levels)
        cert = (2 * ecc_v - ecc_u <= gap).astype(jnp.int32)
        return ecc_u.astype(jnp.int32), cert

    def sample_fn(key: jax.Array, carry):
        keys = jax.random.split(key, batch)
        ecc_u, cert = jax.vmap(one)(keys)
        hist = jax.ops.segment_sum(jnp.ones((batch,), jnp.int32), ecc_u,
                                   num_segments=bins_pad)
        data = {"cert": jnp.sum(cert), "ecc_hist": hist}
        return StateFrame(num=jnp.int32(batch), data=data), carry

    return sample_fn


def frame_template(g: Graph, pad_to: Optional[int] = None):
    bins_pad = pad_to or (g.n + 1)
    return {"cert": jnp.zeros((), jnp.int32),
            "ecc_hist": jnp.zeros((bins_pad,), jnp.int32)}


def diameter_estimate(ecc_hist: np.ndarray) -> float:
    """Best lower bound seen: max occupied bin of the ecc(u) histogram."""
    occupied = np.nonzero(np.asarray(ecc_hist) > 0)[0]
    return float(occupied.max()) if occupied.size else 0.0

"""CSR graph container (undirected, unweighted — as in the paper's instances).

Both a CSR view (``indptr``/``indices`` + a max-degree padded variant for
O(Δ) neighbor gathers) and an edge-parallel COO view (``src``/``dst``, each
undirected edge stored as two arcs) are kept: BFS uses the COO view
(segment-sum frontier expansion — the TPU-idiomatic dense form), path
backtracking uses the padded CSR view (O(Δ) per step).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.tree_util.register_dataclass,
         data_fields=("indptr", "indices_padded", "src", "dst"),
         meta_fields=("n", "m_arcs", "max_degree"))
@dataclasses.dataclass(frozen=True)
class Graph:
    n: int                     # static — number of vertices
    m_arcs: int                # static — number of directed arcs (2·|E|)
    max_degree: int            # static
    indptr: jax.Array          # (n+1,) int32
    indices_padded: jax.Array  # (m_arcs + max_degree,) int32, sentinel-padded
    src: jax.Array             # (m_arcs,) int32, sorted by src
    dst: jax.Array             # (m_arcs,) int32

    def degree(self, v: jax.Array) -> jax.Array:
        return self.indptr[v + 1] - self.indptr[v]

    def neighbors_padded(self, v: jax.Array) -> jax.Array:
        """(max_degree,) neighbor ids; slots ≥ degree(v) hold sentinel ``n``."""
        start = self.indptr[v]
        nbrs = jax.lax.dynamic_slice_in_dim(self.indices_padded, start,
                                            self.max_degree)
        slot = jnp.arange(self.max_degree, dtype=jnp.int32)
        return jnp.where(slot < self.degree(v), nbrs, jnp.int32(self.n))


def from_edges(n: int, edges: np.ndarray) -> Graph:
    """Build an undirected simple Graph from an (E,2) int array of edges.

    Self-loops and duplicate edges are removed; each edge becomes two arcs.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    e = edges[edges[:, 0] != edges[:, 1]] if edges.size else edges
    if e.size:
        lo = np.minimum(e[:, 0], e[:, 1])
        hi = np.maximum(e[:, 0], e[:, 1])
        und = np.unique(lo * n + hi)
        lo, hi = und // n, und % n
    else:
        lo = hi = np.zeros(0, dtype=np.int64)
    src = np.concatenate([lo, hi]).astype(np.int32)
    dst = np.concatenate([hi, lo]).astype(np.int32)
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int32)
    np.add.at(indptr, src_s + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    max_degree = max(int((indptr[1:] - indptr[:-1]).max(initial=1)), 1)
    # sentinel-pad the indices tail so dynamic_slice(start, max_degree) is safe
    indices_padded = np.concatenate([dst_s, np.full(max_degree, n, np.int32)])
    return Graph(n=n, m_arcs=int(src_s.size), max_degree=max_degree,
                 indptr=jnp.asarray(indptr),
                 indices_padded=jnp.asarray(indices_padded),
                 src=jnp.asarray(src_s), dst=jnp.asarray(dst_s))

"""Triangle counting via wedge sampling — a second ADS workload on the
epoch engine.

SAMPLE() draws a uniformly random *wedge* (a path u–v–w centred at v) and
tests whether the closing edge {u, w} exists.  With W = Σ_v d_v(d_v−1)/2
total wedges and T triangles, each triangle closes exactly 3 wedges, so the
closure probability is p = 3T/W and T̂ = p̂·W/3 is an unbiased estimator
(Seshadhri et al., "Triadic measures on graphs: the power of wedge
sampling").  The per-sample cost is O(max_degree) — no BFS — which makes
this the cheap, high-throughput counterpart to KADABRA's per-sample BFS.

Frame layout (mirrors KADABRA's per-vertex counts so every
:class:`~repro.core.frames.FrameStrategy` including SHARED_FRAME sharding
exercises a real vector reduction):

    frame.num     — number of wedges sampled
    frame.data    — (n_pad,) int32: closed-wedge counts by centre vertex

The stopping rule is :class:`~repro.core.stopping.WedgeClosureCondition`
(Hoeffding on p; verdict depends only on ``num`` ⇒ shard-safe).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.frames import StateFrame
from .csr import Graph


def wedge_weights(g: Graph) -> Tuple[np.ndarray, float]:
    """Per-vertex wedge counts d_v(d_v−1)/2 and their total W."""
    deg = (np.asarray(g.indptr[1:]) - np.asarray(g.indptr[:-1])).astype(np.float64)
    w = deg * (deg - 1.0) / 2.0
    return w, float(w.sum())


def triangles_exact(g: Graph) -> float:
    """Exact triangle count via trace(A³)/6 — test oracle (small graphs)."""
    a = np.zeros((g.n, g.n), dtype=np.int64)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    a[src, dst] = 1
    return float(np.trace(a @ a @ a)) / 6.0


def make_wedge_sample_fn(g: Graph, batch: int, *,
                         pad_to: Optional[int] = None):
    """Build SAMPLE() — one vectorized round of ``batch`` wedge samples."""
    n = g.n
    n_pad = pad_to or n
    w, w_total = wedge_weights(g)
    assert w_total > 0, "graph has no wedges (max degree < 2)"
    cum = jnp.asarray(np.cumsum(w), jnp.float32)

    def one(key: jax.Array) -> Tuple[jax.Array, jax.Array]:
        kv, ki, kj = jax.random.split(key, 3)
        # centre v ∝ d_v(d_v−1)/2 via inverse-CDF. Draw u against the f32
        # cumsum's own total (not the f64 w_total): a draw in the rounding
        # gap past cum[-1] would otherwise land on an arbitrary vertex.
        u = jax.random.uniform(kv, (), minval=0.0, maxval=cum[-1])
        v = jnp.searchsorted(cum, u, side="right").astype(jnp.int32)
        v = jnp.minimum(v, n - 1)
        d = g.degree(v)
        # unordered pair of distinct neighbour slots, uniform over d·(d−1)
        i = jax.random.randint(ki, (), 0, jnp.maximum(d, 1), jnp.int32)
        j0 = jax.random.randint(kj, (), 0, jnp.maximum(d - 1, 1), jnp.int32)
        j = j0 + (j0 >= i).astype(jnp.int32)
        nbrs = g.neighbors_padded(v)
        a, b = nbrs[i], nbrs[j]
        # closing-edge membership test: b ∈ N(a). Guard b < n so a sentinel
        # slot (id n, present in every padded neighbour list) can never
        # report a spurious closed wedge if v has degree < 2.
        closed = jnp.logical_and(b < n, jnp.any(g.neighbors_padded(a) == b))
        return v, closed

    def sample_fn(key: jax.Array, carry):
        keys = jax.random.split(key, batch)
        centres, closed = jax.vmap(one)(keys)
        counts = jax.ops.segment_sum(closed.astype(jnp.int32), centres,
                                     num_segments=n_pad)
        return StateFrame(num=jnp.int32(batch), data=counts), carry

    return sample_fn


def triangle_estimate(counts: np.ndarray, num: float, w_total: float) -> float:
    """T̂ = p̂·W/3 from accumulated closed-wedge counts."""
    p_hat = float(np.sum(counts)) / max(float(num), 1.0)
    return p_hat * w_total / 3.0

"""Synthetic graph generators matched to the paper's instance categories
(App. E): social/hyperlink (Erdős–Rényi / Barabási–Albert: low diameter) and
infrastructure/road (2-D grids: high diameter).  The paper's 27 KONECT/SNAP
graphs are not redistributable in this container; see DESIGN.md §8."""

from __future__ import annotations

import numpy as np

from .csr import Graph, from_edges


def erdos_renyi(n: int, m_edges: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    # sample with replacement then dedup inside from_edges; oversample a bit
    e = rng.integers(0, n, size=(int(m_edges * 1.15) + 8, 2))
    g = from_edges(n, e)
    return _ensure_connected_core(g, n, e, seed)


def barabasi_albert(n: int, m_per: int = 3, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    targets = list(range(m_per + 1))
    repeated: list[int] = list(targets)
    edges = []
    for v in range(m_per + 1, n):
        chosen = rng.choice(repeated, size=m_per, replace=False) \
            if len(set(repeated)) >= m_per else rng.integers(0, v, size=m_per)
        for t in np.atleast_1d(chosen):
            edges.append((v, int(t)))
            repeated.append(int(t))
        repeated.extend([v] * m_per)
    return from_edges(n, np.array(edges))


def grid2d(rows: int, cols: int) -> Graph:
    """Road-network analog: high diameter, degree ≤ 4."""
    n = rows * cols
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if c + 1 < cols:
                edges.append((v, v + 1))
            if r + 1 < rows:
                edges.append((v, v + cols))
    return from_edges(n, np.array(edges))


def _ensure_connected_core(g: Graph, n: int, e: np.ndarray, seed: int) -> Graph:
    """Attach isolated vertices to vertex 0 so ER graphs have one big CC
    (keeps test oracles simple; KADABRA itself handles multiple CCs)."""
    deg = np.asarray(g.indptr[1:]) - np.asarray(g.indptr[:-1])
    isolated = np.where(deg == 0)[0]
    if isolated.size == 0:
        return g
    extra = np.stack([isolated, np.zeros_like(isolated)], axis=1)
    return from_edges(n, np.concatenate([np.asarray(e), extra], axis=0))

"""Monte-Carlo s–t reachability under edge percolation — a third ADS
workload on the epoch engine.

Each undirected edge survives independently with probability π; one sample
draws a percolated subgraph and reports whether ``t`` is reachable from
``s`` (a level-synchronous masked frontier expansion, the BFS machinery of
:mod:`repro.graphs.bfs` without σ counting).  The reachability probability
p = Pr[s ⇝ t] is the two-terminal network-reliability measure; computing it
exactly is #P-hard, which is precisely why the adaptive Monte-Carlo
estimator (with an empirical-Bernstein stopping rule that exploits the
vanishing variance near p ∈ {0, 1}) is the method of choice.

Frame layout:

    frame.num  — number of percolation samples
    frame.data — {"s1": Σx, "s2": Σx²  (scalars, fully reduced under every
                  strategy), "hits": (n_pad,) int32 per-vertex reached
                  counts (a vector leaf so SHARED_FRAME exercises a real
                  reduce-scatter)}

Stopping rule: :class:`~repro.core.stopping.PercolationCondition`.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.frames import StateFrame
from .csr import Graph


def arc_edge_ids(g: Graph) -> Tuple[np.ndarray, int]:
    """Map each directed arc to its undirected edge id.

    Returns ``(ids (m_arcs,) int32, m_edges)``; the two arcs of an edge share
    one id, so one Bernoulli draw per edge percolates both directions.
    """
    src = np.asarray(g.src).astype(np.int64)
    dst = np.asarray(g.dst).astype(np.int64)
    key = np.minimum(src, dst) * g.n + np.maximum(src, dst)
    uniq, inv = np.unique(key, return_inverse=True)
    return inv.astype(np.int32), int(uniq.size)


def reached_masked(g: Graph, arc_ids: jax.Array, edge_alive: jax.Array,
                   s: jax.Array) -> jax.Array:
    """(n,) bool — vertices reachable from ``s`` using surviving edges."""
    n = g.n
    alive = edge_alive[arc_ids]
    reached0 = jnp.zeros((n,), bool).at[s].set(True)

    def cond(st):
        _, changed, it = st
        return jnp.logical_and(changed, it < n)

    def body(st):
        r, _, it = st
        contrib = jnp.logical_and(r[g.src], alive).astype(jnp.int32)
        agg = jax.ops.segment_sum(contrib, g.dst, num_segments=n) > 0
        new = jnp.logical_or(r, agg)
        return new, jnp.any(new != r), it + 1

    r, _, _ = jax.lax.while_loop(
        cond, body, (reached0, jnp.bool_(True), jnp.int32(0)))
    return r


def make_percolation_sample_fn(g: Graph, s: int, t: int, pi: float,
                               batch: int, *, pad_to: Optional[int] = None):
    """Build SAMPLE() — one vectorized round of ``batch`` percolations."""
    n = g.n
    n_pad = pad_to or n
    ids_np, m_edges = arc_edge_ids(g)
    arc_ids = jnp.asarray(ids_np)
    s_, t_ = jnp.int32(s), jnp.int32(t)

    def one(key: jax.Array) -> Tuple[jax.Array, jax.Array]:
        edge_alive = jax.random.uniform(key, (m_edges,)) < pi
        r = reached_masked(g, arc_ids, edge_alive, s_)
        return r[t_], r

    def sample_fn(key: jax.Array, carry):
        keys = jax.random.split(key, batch)
        x, r = jax.vmap(one)(keys)
        x32 = x.astype(jnp.int32)
        hits = jnp.pad(jnp.sum(r, axis=0, dtype=jnp.int32), (0, n_pad - n))
        data = {"s1": jnp.sum(x32), "s2": jnp.sum(x32 * x32), "hits": hits}
        return StateFrame(num=jnp.int32(batch), data=data), carry

    return sample_fn


def frame_template(g: Graph, pad_to: Optional[int] = None):
    n_pad = pad_to or g.n
    return {"s1": jnp.zeros((), jnp.int32), "s2": jnp.zeros((), jnp.int32),
            "hits": jnp.zeros((n_pad,), jnp.int32)}


def reachability_exact(g: Graph, s: int, t: int, pi: float,
                       max_edges: int = 20) -> float:
    """Exact Pr[s ⇝ t] by enumerating all 2^m edge subsets — test oracle.

    Feasible only for tiny graphs (m ≤ ``max_edges``); uses union–find per
    subset.
    """
    ids, m = arc_edge_ids(g)
    assert m <= max_edges, f"{m} edges is too many for exact enumeration"
    # one (u, v) pair per undirected edge
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    first_arc = np.zeros(m, dtype=np.int64)
    seen = set()
    for a, e in enumerate(ids):
        if int(e) not in seen:
            seen.add(int(e))
            first_arc[e] = a
    eu, ev = src[first_arc], dst[first_arc]

    def find(parent, x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    prob = 0.0
    for mask in range(1 << m):
        parent = list(range(g.n))
        k = 0
        for e in range(m):
            if mask >> e & 1:
                k += 1
                ru, rv = find(parent, int(eu[e])), find(parent, int(ev[e]))
                parent[ru] = rv
        if find(parent, s) == find(parent, t):
            prob += (pi ** k) * ((1.0 - pi) ** (m - k))
    return prob

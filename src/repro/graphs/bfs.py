"""Level-synchronous BFS with shortest-path counting + uniform path sampling.

This is SAMPLE() of the paper's Algorithm 1 for KADABRA: pick (s,t) u.a.r.,
run a BFS from s counting shortest paths (σ), then backtrack from t choosing
predecessors with probability σ(u)/Σσ — a uniform random shortest s–t path.

TPU adaptation (DESIGN.md §2/§8): the original uses a sequential
bidirectional BFS per sample; here BFS levels are *edge-parallel*
(segment-sum frontier expansion — dense, MXU/VPU-friendly, vmappable over a
batch of samples) and backtracking gathers ≤ max_degree neighbors per step.
The per-level σ renormalization keeps path counts in float32 range: only
*ratios within one level* matter for sampling, so scaling σ uniformly at a
level is distribution-preserving.

The CSR frontier expansion is the kernel hot spot; ``kernels/bfs_frontier``
is the Pallas TPU version of one level and this file is its oracle.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .csr import Graph

INF = jnp.int32(0x3FFFFFFF)
_SIGMA_CAP = 1e30


@partial(jax.jit, static_argnames=("max_levels", "early_exit"))
def bfs_sssp(g: Graph, s: jax.Array, t: jax.Array = None, *,
             max_levels: int, early_exit: bool = True
             ) -> Tuple[jax.Array, jax.Array]:
    """Distances and (rescaled) shortest-path counts from ``s``.

    Returns ``dist (n,) int32`` (INF if unreachable) and ``sigma (n,) f32``.
    If ``early_exit`` and ``t`` is given, stops once t's level is complete
    (σ(t) is final at that point — all its predecessors are one level up).
    """
    n = g.n
    dist = jnp.full((n,), INF, jnp.int32).at[s].set(0)
    sigma = jnp.zeros((n,), jnp.float32).at[s].set(1.0)
    t = jnp.int32(-1) if t is None else t

    def cond(st):
        level, dist, sigma, frontier_size = st
        go = jnp.logical_and(frontier_size > 0, level < max_levels)
        if early_exit:
            go = jnp.logical_and(go, jnp.where(t >= 0, dist[t] == INF, True))
        return go

    def body(st):
        level, dist, sigma, _ = st
        active = dist[g.src] == level
        contrib = jnp.where(active, sigma[g.src], 0.0)
        agg = jax.ops.segment_sum(contrib, g.dst, num_segments=n)
        newly = jnp.logical_and(dist == INF, agg > 0.0)
        dist = jnp.where(newly, level + 1, dist)
        # per-level renormalization against float32 overflow: scaling all σ of
        # the new level uniformly preserves the within-level ratios that path
        # sampling uses, so the sampled-path distribution is unchanged.
        mx = jnp.max(jnp.where(newly, agg, 0.0))
        scale = jnp.where(mx > _SIGMA_CAP, _SIGMA_CAP / mx, 1.0)
        sigma = jnp.where(newly, agg * scale, sigma)
        return (level + 1, dist, sigma, jnp.sum(newly.astype(jnp.int32)))

    _, dist, sigma, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), dist, sigma, jnp.int32(1)))
    return dist, sigma


@partial(jax.jit, static_argnames=("max_levels",))
def eccentricity(g: Graph, s: jax.Array, *, max_levels: int) -> jax.Array:
    dist, _ = bfs_sssp(g, s, None, max_levels=max_levels, early_exit=False)
    return jnp.max(jnp.where(dist == INF, 0, dist))


@partial(jax.jit, static_argnames=("max_iters",))
def connected_components(g: Graph, *, max_iters: int = 10_000) -> jax.Array:
    """Component labels via min-label propagation (paper C.1 uses CCs to skip
    disconnected pairs)."""
    n = g.n
    labels = jnp.arange(n, dtype=jnp.int32)

    def cond(st):
        labels, changed, it = st
        return jnp.logical_and(changed, it < max_iters)

    def body(st):
        labels, _, it = st
        neigh_min = jax.ops.segment_min(labels[g.src], g.dst, num_segments=n)
        new = jnp.minimum(labels, neigh_min)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (labels, True, jnp.int32(0)))
    return labels


@partial(jax.jit, static_argnames=("max_len",))
def sample_path(g: Graph, key: jax.Array, s: jax.Array, t: jax.Array,
                dist: jax.Array, sigma: jax.Array, *, max_len: int
                ) -> jax.Array:
    """Uniform random shortest s–t path → bool mask of *internal* vertices.

    Walks backward from t, choosing each predecessor u (a neighbor with
    dist[u] = dist[cur]−1) with probability σ(u)/Σσ via Gumbel-max over the
    ≤ max_degree padded neighbor slots.  If t is unreachable the mask is all
    False (the sample contributes x_i = 0 — the correct estimator term).
    """
    n = g.n
    reachable = dist[t] != INF
    dist_pad = jnp.concatenate([dist, jnp.full((1,), INF, jnp.int32)])
    sigma_pad = jnp.concatenate([sigma, jnp.zeros((1,), jnp.float32)])

    def step(carry, k):
        cur, mask = carry
        done = jnp.logical_or(cur == s, ~reachable)
        nbrs = g.neighbors_padded(cur)                  # (Δ,) with sentinel n
        w = jnp.where(dist_pad[nbrs] == dist[cur] - 1, sigma_pad[nbrs], 0.0)
        gum = -jnp.log(-jnp.log(
            jax.random.uniform(k, w.shape, minval=1e-12, maxval=1.0)))
        scores = jnp.where(w > 0.0, jnp.log(w) + gum, -jnp.inf)
        nxt = nbrs[jnp.argmax(scores)]
        cur2 = jnp.where(done, cur, nxt)
        is_internal = jnp.logical_and(cur2 != s, cur2 != t)
        mask = mask.at[cur2].set(jnp.where(
            jnp.logical_and(~done, is_internal), True, mask[cur2]))
        return (cur2, mask), None

    keys = jax.random.split(key, max_len)
    (_, mask), _ = jax.lax.scan(step, (t, jnp.zeros((n,), bool)), keys)
    return jnp.where(reachable, mask, False)

"""Graph substrate for the KADABRA case study (paper §2.2–2.3).

CSR graphs as JAX arrays, synthetic generators, level-synchronous BFS with
shortest-path counting, uniform shortest-path sampling, the exact Brandes
oracle, and the KADABRA preprocessing + adaptive-sampling driver.
"""
from .csr import Graph, from_edges
from .gens import erdos_renyi, barabasi_albert, grid2d
from .bfs import bfs_sssp, connected_components, eccentricity, sample_path
from .brandes import brandes_exact
from .kadabra import (KadabraParams, frame_template, make_sample_fn,
                      preprocess, run_kadabra)
from .reachability import (make_percolation_sample_fn, reachability_exact,
                           reached_masked)
from .triangles import (make_wedge_sample_fn, triangle_estimate,
                        triangles_exact, wedge_weights)
from .diameter import (diameter_estimate, diameter_exact, double_sweep,
                       make_sweep_sample_fn)

__all__ = [
    "Graph", "from_edges", "erdos_renyi", "barabasi_albert", "grid2d",
    "bfs_sssp", "connected_components", "eccentricity", "sample_path",
    "brandes_exact", "KadabraParams", "preprocess", "make_sample_fn",
    "run_kadabra", "frame_template",
    "make_wedge_sample_fn", "triangles_exact", "triangle_estimate",
    "wedge_weights",
    "make_percolation_sample_fn", "reachability_exact", "reached_masked",
    "diameter_estimate", "diameter_exact", "double_sweep",
    "make_sweep_sample_fn",
]

"""Graph substrate for the KADABRA case study (paper §2.2–2.3).

CSR graphs as JAX arrays, synthetic generators, level-synchronous BFS with
shortest-path counting, uniform shortest-path sampling, the exact Brandes
oracle, and the KADABRA preprocessing + adaptive-sampling driver.
"""
from .csr import Graph, from_edges
from .gens import erdos_renyi, barabasi_albert, grid2d
from .bfs import bfs_sssp, connected_components, eccentricity, sample_path
from .brandes import brandes_exact
from .kadabra import (KadabraParams, frame_template, make_sample_fn,
                      preprocess, run_kadabra)

__all__ = [
    "Graph", "from_edges", "erdos_renyi", "barabasi_albert", "grid2d",
    "bfs_sssp", "connected_components", "eccentricity", "sample_path",
    "brandes_exact", "KadabraParams", "preprocess", "make_sample_fn",
    "run_kadabra", "frame_template",
]

"""KADABRA (Borassi & Natale 2016) on the epoch-based engine — the paper's
case study (§2.3, §4).

Phases (mirroring the original implementation + the paper's C.1 tricks):

1. ``preprocess`` — connected components (skip disconnected pairs cheaply),
   vertex-diameter upper bound via double-sweep BFS, ω from the VC bound.
2. adaptive sampling via :mod:`repro.core.epoch` with any
   :class:`~repro.core.frames.FrameStrategy` — this is where the paper's
   local-/shared-/indexed-frame algorithms run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.epoch import EpochConfig, EpochState, run_virtual, run_worker
from ..core.frames import FrameStrategy, StateFrame, shard_frame_pad
from ..core.stopping import KadabraCondition, kadabra_omega
from .bfs import INF, bfs_sssp, connected_components, eccentricity, sample_path
from .csr import Graph


@dataclasses.dataclass(frozen=True)
class KadabraParams:
    eps: float = 0.05
    delta: float = 0.1
    batch: int = 16           # samples per sampling round (vectorized SAMPLE)
    rounds_per_epoch: int = 4  # paper's N (App. C.2) in units of rounds
    max_epochs: int = 4096
    xi: float = 0.0            # App. C.3 coordinator-cadence heuristic
    c_omega: float = 0.5


@dataclasses.dataclass(frozen=True)
class Preprocessed:
    omega: float
    vd_upper: int          # vertex-diameter upper bound
    components: jax.Array  # (n,) int32 labels
    diam_levels: int       # BFS level budget


def preprocess(g: Graph, eps: float, delta: float, c_omega: float = 0.5,
               seed: int = 0) -> Preprocessed:
    comps = connected_components(g)
    # double-sweep: ecc from a random vertex, then from the farthest vertex.
    max_levels = g.n  # worst case; each BFS exits when the frontier empties
    v0 = jnp.int32(seed % g.n)
    dist0, _ = bfs_sssp(g, v0, None, max_levels=max_levels, early_exit=False)
    far = jnp.argmax(jnp.where(dist0 == INF, -1, dist0)).astype(jnp.int32)
    ecc = int(eccentricity(g, far, max_levels=max_levels))
    diam_ub = 2 * max(ecc, 1)          # diam ≤ 2·ecc(u) for unweighted graphs
    vd_upper = diam_ub + 1             # vertices on the longest shortest path
    omega = kadabra_omega(eps, delta, vd_upper, c=c_omega)
    return Preprocessed(omega=float(omega), vd_upper=vd_upper,
                        components=comps, diam_levels=diam_ub + 1)


def make_sample_fn(g: Graph, pre: Preprocessed, batch: int, *,
                   pad_to: Optional[int] = None):
    """Build SAMPLE() — one vectorized round of ``batch`` path samples.

    Frame data: per-vertex counts Σ x_i(v), optionally padded to ``pad_to``
    (for SHARED_FRAME reduce-scatter divisibility).
    """
    n = g.n
    n_pad = pad_to or n
    max_levels = pre.diam_levels
    max_len = pre.vd_upper

    def one(key: jax.Array) -> jax.Array:
        ks, kt, kp = jax.random.split(key, 3)
        s = jax.random.randint(ks, (), 0, n, dtype=jnp.int32)
        # t uniform over vertices ≠ s (rejection-free)
        t = (s + 1 + jax.random.randint(kt, (), 0, n - 1, jnp.int32)) % n
        same_cc = pre.components[s] == pre.components[t]
        dist, sigma = bfs_sssp(g, s, t, max_levels=max_levels, early_exit=True)
        mask = sample_path(g, kp, s, t, dist, sigma, max_len=max_len)
        # disconnected pair ⇒ x_i ≡ 0 (correct estimator term; C.1 trick just
        # skips the BFS work — here the lanes are fixed-shape anyway)
        return jnp.where(same_cc, mask, False)

    def sample_fn(key: jax.Array, carry):
        keys = jax.random.split(key, batch)
        xs = jax.vmap(one)(keys)                       # (batch, n) bool
        counts = jnp.sum(xs, axis=0, dtype=jnp.int32)  # Σ x_i(v)
        counts = jnp.pad(counts, (0, n_pad - n))
        return StateFrame(num=jnp.int32(batch), data=counts), carry

    return sample_fn


def frame_template(g: Graph, pad_to: Optional[int] = None) -> jax.Array:
    return jnp.zeros((pad_to or g.n,), jnp.int32)


def run_kadabra(g: Graph, params: KadabraParams, *,
                strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME,
                world: int = 1, seed: int = 0,
                pre: Optional[Preprocessed] = None,
                ) -> Tuple[np.ndarray, EpochState, Preprocessed]:
    """End-to-end KADABRA with ``world`` (virtual) parallel workers.

    Returns (btilde estimates (n,), final EpochState, Preprocessed).
    """
    pre = pre or preprocess(g, params.eps, params.delta, params.c_omega, seed)
    pad = shard_frame_pad(g.n, world) if strategy == FrameStrategy.SHARED_FRAME \
        else g.n
    sample_fn = make_sample_fn(g, pre, params.batch, pad_to=pad)
    cond = KadabraCondition(eps=params.eps, delta=params.delta,
                            omega=pre.omega, n_vertices=g.n)

    def check_fn(frame: StateFrame):
        # padded tail (zeros) yields f,g = small values at b̃=0; for the
        # sharded check the per-shard max over real vertices is what matters —
        # padding zeros never *block* stopping because f,g at b̃=0,τ>0 are the
        # minimum of the bound; correctness verified in tests.
        return cond(frame)

    cfg = EpochConfig(strategy=strategy,
                      rounds_per_epoch=params.rounds_per_epoch,
                      max_epochs=params.max_epochs, xi=params.xi)

    if world == 1:
        from ..core.frames import sequential_collectives
        st = run_worker(sample_fn, check_fn, frame_template(g, pad), None,
                        jax.random.key(seed), cfg,
                        colls=sequential_collectives(),
                        seed_scalar=jnp.asarray(seed, jnp.uint32),
                        worker_id=jnp.int32(0))
        total = st.total
        counts = np.asarray(total.data)[: g.n]
        tau = float(total.num)
    else:
        st = run_virtual(sample_fn, check_fn, frame_template(g, pad), None,
                         seed, world, cfg)
        # per-worker views of the (replicated or sharded) total
        if strategy == FrameStrategy.SHARED_FRAME:
            counts = np.asarray(st.total.data).reshape(-1)[: g.n]
        else:
            counts = np.asarray(jax.tree.map(lambda x: x[0], st.total.data))[: g.n]
        tau = float(np.asarray(st.total.num)[0] if np.ndim(st.total.num) else st.total.num)

    btilde = counts.astype(np.float64) / max(tau, 1.0)
    return btilde, st, pre

"""Alias tables (Walker/Vose) and the weighted-mean ADS workload.

Weighted random sampling per Hübschle-Schneider & Sanders ("Parallel
Weighted Random Sampling"): an alias table turns n arbitrary positive
weights into O(1)-time draws — bucket ``i = ⌊u₁·n⌋`` is kept with
probability ``prob[i]`` and redirected to ``alias[i]`` otherwise.
Construction is the two-stack Vose method, O(n) and exact in float64.

The ADS instance on top estimates the weighted mean μ = Σᵢ pᵢ·xᵢ of a
bounded value vector x under the weight distribution p ∝ w, stopping on
*relative* standard error (:class:`~repro.core.stopping.RelativeErrorCondition`)
— the adaptive-sampling analog of H&S's fixed-size batches.

Frame layout (all-integer so every strategy, INDEXED_FRAME bit-identity
included, reduces exactly):

    frame.num  — number of draws
    frame.data — {"s1": Σ xq   (int32 scalar),
                  "s2": Σ xq²  (int32 scalar),
                  "hist": (n_pad,) int32 per-item draw counts (vector leaf
                          so SHARED_FRAME exercises a real reduce-scatter)}

Values are quantized to integers ``xq ∈ [0, value_scale)`` with
``x = xq / value_scale``; int32 moment sums stay exact as long as
``num · (value_scale−1)² < 2³¹`` (the BENCH presets cap ``max_samples``
accordingly).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.frames import StateFrame

VALUE_SCALE = 32


@dataclasses.dataclass(frozen=True)
class AliasTable:
    """Walker alias table: draw ⌊u₁·n⌋, keep w.p. ``prob``, else ``alias``."""

    n: int
    prob: jax.Array    # (n,) float32 — acceptance threshold per bucket
    alias: jax.Array   # (n,) int32   — redirect target per bucket


def build_alias_table(weights: np.ndarray) -> AliasTable:
    """Vose's O(n) two-stack construction (float64 host-side, then cast)."""
    w = np.asarray(weights, np.float64).reshape(-1)
    if w.size == 0:
        raise ValueError("alias table needs at least one weight")
    if not np.all(np.isfinite(w)) or np.any(w < 0.0):
        raise ValueError("weights must be finite and non-negative")
    total = float(w.sum())
    if total <= 0.0:
        raise ValueError("weights must not all be zero")
    n = w.size
    scaled = w / total * n
    prob = np.ones(n, np.float64)
    alias = np.arange(n, dtype=np.int64)
    small = [i for i in range(n) if scaled[i] < 1.0]
    large = [i for i in range(n) if scaled[i] >= 1.0]
    while small and large:
        s = small.pop()
        g = large.pop()
        prob[s] = scaled[s]
        alias[s] = g
        scaled[g] = (scaled[g] + scaled[s]) - 1.0
        (small if scaled[g] < 1.0 else large).append(g)
    # leftovers are ≈1 up to rounding: keep with probability 1
    for i in small + large:
        prob[i] = 1.0
        alias[i] = i
    return AliasTable(n=n, prob=jnp.asarray(prob, jnp.float32),
                      alias=jnp.asarray(alias, jnp.int32))


def alias_draw_probabilities(table: AliasTable) -> np.ndarray:
    """Exact per-item draw probability implied by the table:

    P(i) = (prob[i] + Σ_{j: alias[j]=i} (1 − prob[j])) / n

    Used by tests to verify construction (must equal wᵢ/Σw up to the f32
    cast of ``prob``).
    """
    prob = np.asarray(table.prob, np.float64)
    alias = np.asarray(table.alias)
    p = prob.copy()
    np.add.at(p, alias, 1.0 - prob)
    return p / table.n


def weighted_mean_exact(weights: np.ndarray, values_q: np.ndarray,
                        value_scale: int = VALUE_SCALE) -> float:
    """Exact estimand μ = Σᵢ pᵢ·(xqᵢ/scale) — the workload oracle (O(n))."""
    w = np.asarray(weights, np.float64)
    x = np.asarray(values_q, np.float64) / float(value_scale)
    return float((w * x).sum() / w.sum())


def make_weighted_sample_fn(table: AliasTable, values_q: jax.Array,
                            batch: int, *, pad_to: Optional[int] = None):
    """Build SAMPLE() — one vectorized round of ``batch`` alias draws.

    The draw itself goes through :func:`repro.kernels.ops.alias_draw`
    (Pallas on TPU, pure-jnp oracle elsewhere); uniforms only *select*
    integer indices, so the accumulated frame is integer-exact and
    identical across strategies for identical keys.
    """
    from ..kernels import ops

    n = table.n
    n_pad = pad_to or n
    values_q = jnp.asarray(values_q, jnp.int32)

    def sample_fn(key: jax.Array, carry) -> Tuple[StateFrame, jax.Array]:
        k1, k2 = jax.random.split(key)
        u1 = jax.random.uniform(k1, (batch,))
        u2 = jax.random.uniform(k2, (batch,))
        idx = ops.alias_draw(table.prob, table.alias, u1, u2)
        xq = values_q[idx]
        hist = jax.ops.segment_sum(jnp.ones((batch,), jnp.int32), idx,
                                   num_segments=n_pad)
        data = {"s1": jnp.sum(xq), "s2": jnp.sum(xq * xq), "hist": hist}
        return StateFrame(num=jnp.int32(batch), data=data), carry

    return sample_fn


def weighted_frame_template(n: int, pad_to: Optional[int] = None):
    n_pad = pad_to or n
    return {"s1": jnp.zeros((), jnp.int32), "s2": jnp.zeros((), jnp.int32),
            "hist": jnp.zeros((n_pad,), jnp.int32)}

"""Weighted random sampling substrate (Hübschle-Schneider & Sanders,
"Parallel Weighted Random Sampling").

Alias tables give O(1) weighted draws after O(n) construction; the ADS
instance built on top estimates a weighted mean adaptively (stop on relative
standard error — :class:`~repro.core.stopping.RelativeErrorCondition`).
"""
from .alias import (AliasTable, alias_draw_probabilities, build_alias_table,
                    make_weighted_sample_fn, weighted_frame_template,
                    weighted_mean_exact)

__all__ = [
    "AliasTable", "build_alias_table", "alias_draw_probabilities",
    "make_weighted_sample_fn", "weighted_frame_template",
    "weighted_mean_exact",
]

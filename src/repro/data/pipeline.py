"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of ``(seed, step, shard, n_shards)`` — the
pipeline has **no mutable state**, so

* resume-from-checkpoint = replay from the recorded step (exactly-once),
* elastic rescale = change ``n_shards``; the global token stream at a step
  is the concatenation over shards and stays identical when the data-axis
  grows/shrinks by integer factors,
* the INDEXED_FRAME determinism story extends to training data (frame index
  ⇒ data indices).

Tokens are zipf-ish (log-uniform ranks, exponent ≈1) with EOS-separated
pseudo-documents, matching LM-loss shapes without shipping a corpus; labels
are next-token shifted.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataCursor:
    """Checkpointable position (serialized into checkpoint meta)."""
    step: int = 0
    seed: int = 0

    def as_meta(self) -> Dict:
        return {"data_step": self.step, "data_seed": self.seed}

    @staticmethod
    def from_meta(meta: Dict) -> "DataCursor":
        return DataCursor(step=int(meta.get("data_step", 0)),
                          seed=int(meta.get("data_seed", 0)))


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    batch: int                 # global batch (over all shards)
    seed: int = 0
    mean_doc_len: int = 512
    eos: int = 0

    def _batch_key(self, step: int, shard: int, n_shards: int) -> jax.Array:
        k = jax.random.key(self.seed)
        k = jax.random.fold_in(k, step)
        # shard-count-independent stream: fold the GLOBAL row index
        rows = self.batch // n_shards
        return jax.random.fold_in(k, shard * rows)

    @partial(jax.jit, static_argnames=("self", "n_shards"))
    def batch_at(self, step: jax.Array, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, jax.Array]:
        """→ {"tokens": (B/n_shards, S), "labels": same} for this shard."""
        rows = self.batch // n_shards
        base = jax.random.key(self.seed)
        base = jax.random.fold_in(base, step)

        def row(r):
            k = jax.random.fold_in(base, shard * rows + r)
            ku, kd = jax.random.split(k)
            u = jax.random.uniform(ku, (self.seq_len + 1,), minval=1e-6)
            # log-uniform ranks ≈ zipf(1); keep 0 reserved for EOS
            ranks = jnp.exp(u * jnp.log(self.vocab - 1.0)).astype(jnp.int32)
            toks = jnp.clip(ranks, 1, self.vocab - 1)
            # EOS-separated pseudo-documents
            de = jax.random.uniform(kd, (self.seq_len + 1,))
            toks = jnp.where(de < 1.0 / self.mean_doc_len, self.eos, toks)
            return toks

        toks = jax.vmap(row)(jnp.arange(rows))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def micro_batches(self, step: jax.Array, n_micro: int, *,
                      shard: int = 0, n_shards: int = 1
                      ) -> Dict[str, jax.Array]:
        """(n_micro, B/n_shards/n_micro, S) leading layout for grad-accum."""
        b = self.batch_at(step, shard, n_shards)
        rows = self.batch // n_shards
        mb = rows // n_micro
        return jax.tree.map(
            lambda x: x[: n_micro * mb].reshape((n_micro, mb) + x.shape[1:]),
            b)

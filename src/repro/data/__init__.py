from .pipeline import TokenStream, DataCursor

__all__ = ["TokenStream", "DataCursor"]

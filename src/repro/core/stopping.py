"""Stopping conditions (CHECKFORSTOP of Algorithm 1).

Every stopping condition is a pure function

    check(frame_total: StateFrame) -> (stop: bool scalar, aux: pytree)

evaluated on a *consistent* reduced state (the epoch engine guarantees
consistency — Prop. 1 of the paper).  Implemented conditions:

* :class:`KadabraCondition` — the paper's case study (App. B): per-vertex
  Bernstein-style bounds ``f, g ≤ ε`` with error budget ``δ_L, δ_U``.
* :class:`HoeffdingCondition` / :class:`EmpiricalBernsteinCondition` —
  generic (ε,δ) mean estimation; used for adaptive metric evaluation
  (serve-side) and as simple test oracles.
* :class:`RelativeErrorCondition` — relative-error (rtol,δ) mean estimation
  via empirical Bernstein; drives the weighted-random-sampling workload.
* :class:`EccentricityGapCondition` — double-sweep diameter estimation:
  stop once a sample certifies the lower/upper eccentricity gap closed.
* :class:`GradVarianceCondition` — adaptive gradient accumulation: stop
  sampling microbatch gradients once the relative standard error of the
  gradient-norm estimate is below target (the framework's "beyond-paper"
  application of ADS to distributed training).

All math is in float32 and fully ``jit``/``vmap``/``shard_map`` compatible.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .frames import StateFrame


def _log_safe(x):
    return jnp.log(jnp.maximum(x, 1e-30))


def hoeffding_tau_needed(eps: float, delta: float,
                         value_range: float = 1.0) -> jax.Array:
    """Hoeffding (ε,δ) sample bound: τ ≥ (range²/(2ε²))·log(2/δ)."""
    return (value_range ** 2) / (2.0 * eps ** 2) * jnp.log(
        jnp.float32(2.0 / delta))


def empirical_bernstein_half_width(s1: jax.Array, s2: jax.Array,
                                   tau: jax.Array, delta: float,
                                   value_range: float = 1.0):
    """Maurer–Pontil EB half-width from the running moments Σx, Σx².

    Returns (mean, half_width) with
    half = sqrt(2 V̂ log(3/δ)/τ) + 3 R log(3/δ)/τ.
    """
    mean = s1 / tau
    var = jnp.maximum(s2 / tau - mean ** 2, 0.0)
    log3d = jnp.log(jnp.float32(3.0 / delta))
    half = jnp.sqrt(2.0 * var * log3d / tau) + 3.0 * value_range * log3d / tau
    return mean, half


@dataclasses.dataclass(frozen=True)
class KadabraCondition:
    """KADABRA stopping condition (paper App. B).

    f(b̃, δ_L, ω, τ) = (1/τ)·log(1/δ_L)·[ 1/3 − ω/τ + sqrt((1/3 − ω/τ)² + 2 b̃ ω / log(1/δ_L)) ]
    g(b̃, δ_U, ω, τ) = (1/τ)·log(1/δ_U)·[ 1/3 + ω/τ + sqrt((1/3 + ω/τ)² + 2 b̃ ω / log(1/δ_U)) ]

    Note the ω/τ terms use ω̄ = ω·(log(1/δ)/τ is already folded as in [6]);
    we follow the exact formulas as printed in the paper, which use the ratio
    ``ω/τ`` scaled inside the bracket by the per-vertex log terms.  Stop when
    ``f ≤ ε`` and ``g ≤ ε`` for every vertex, or when ``τ ≥ ω`` (the static
    VC-dimension bound then guarantees the error).

    δ_L(v) = δ_U(v) = δ/(2n) (uniform allocation — conservative; the original
    runs an extra budget-allocation pass, see DESIGN.md §8).
    """

    eps: float
    delta: float
    omega: float          # maximal number of samples (from preprocessing)
    n_vertices: int       # number of vertices (frame.data size)

    def per_vertex_bounds(self, btilde: jax.Array, tau: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
        """App. B, verbatim:

        f = (1/τ)·log(1/δ_L)·[ 1/3 − ω/τ + sqrt((1/3 − ω/τ)² + 2·b̃·ω/log(1/δ_L)) ]
        g = (1/τ)·log(1/δ_U)·[ 1/3 + ω/τ + sqrt((1/3 + ω/τ)² + 2·b̃·ω/log(1/δ_U)) ]

        (f ≥ 0 always: the bracket is of the form −x + sqrt(x² + B) ≥ 0.)
        """
        dl = self.delta / (2.0 * self.n_vertices)
        L = -_log_safe(jnp.asarray(dl, jnp.float32))   # log(1/δ_L) = log(1/δ_U)
        tau = jnp.maximum(tau.astype(jnp.float32), 1.0)
        r = self.omega / tau
        b = btilde.astype(jnp.float32)
        f = (L / tau) * ((1.0 / 3.0 - r) +
                         jnp.sqrt((1.0 / 3.0 - r) ** 2 + 2.0 * b * self.omega / L))
        g = (L / tau) * ((1.0 / 3.0 + r) +
                         jnp.sqrt((1.0 / 3.0 + r) ** 2 + 2.0 * b * self.omega / L))
        return f, g

    def __call__(self, frame: StateFrame):
        tau = frame.num.astype(jnp.float32)
        counts = frame.data  # per-vertex Σ x_i(v)
        btilde = counts.astype(jnp.float32) / jnp.maximum(tau, 1.0)
        f, g = self.per_vertex_bounds(btilde, tau)
        bounds_ok = jnp.logical_and(jnp.max(f) <= self.eps, jnp.max(g) <= self.eps)
        omega_hit = tau >= self.omega
        stop = jnp.logical_and(tau > 0, jnp.logical_or(bounds_ok, omega_hit))
        aux = {"btilde": btilde, "max_f": jnp.max(f), "max_g": jnp.max(g), "tau": tau}
        return stop, aux


@dataclasses.dataclass(frozen=True)
class HoeffdingCondition:
    """Stop when the Hoeffding (ε,δ) bound for a bounded mean holds:
    τ ≥ (range²/(2ε²))·log(2/δ).  frame.data = Σ x_i (scalar or vector)."""

    eps: float
    delta: float
    value_range: float = 1.0

    def __call__(self, frame: StateFrame):
        tau = frame.num.astype(jnp.float32)
        need = hoeffding_tau_needed(self.eps, self.delta, self.value_range)
        mean = jax.tree.map(
            lambda s: s.astype(jnp.float32) / jnp.maximum(tau, 1.0), frame.data)
        return tau >= need, {"mean": mean, "tau": tau, "tau_needed": need}


@dataclasses.dataclass(frozen=True)
class EmpiricalBernsteinCondition:
    """Empirical-Bernstein stopping (Maurer & Pontil) for mean estimation with
    data-dependent sample size; frame.data = {"s1": Σx, "s2": Σx²}.

    half-width = sqrt(2 V̂ log(3/δ)/τ) + 3 R log(3/δ)/τ  ≤ ε  ⇒ stop.
    """

    eps: float
    delta: float
    value_range: float = 1.0

    def __call__(self, frame: StateFrame):
        tau = jnp.maximum(frame.num.astype(jnp.float32), 2.0)
        mean, half = empirical_bernstein_half_width(
            frame.data["s1"].astype(jnp.float32),
            frame.data["s2"].astype(jnp.float32),
            tau, self.delta, self.value_range)
        stop = jnp.logical_and(frame.num >= 2, jnp.max(half) <= self.eps)
        return stop, {"mean": mean, "half_width": half, "tau": frame.num}


@dataclasses.dataclass(frozen=True)
class WedgeClosureCondition:
    """Stopping rule for triangle counting via wedge sampling.

    Each sample closes (x=1) or doesn't (x=0) a uniformly random wedge, so
    the closure probability p = 3T/W (T triangles, W wedges) is a bounded
    mean and the Hoeffding bound applies: stop once

        τ ≥ (1/(2ε²))·log(2/δ)

    which guarantees |p̂ − p| ≤ ε w.p. ≥ 1−δ, i.e. a triangle-count error of
    at most ε·W/3.  The verdict depends only on ``frame.num`` (fully reduced
    under every strategy, including SHARED_FRAME shards), so this condition
    is shard-safe by construction.
    """

    eps: float                # absolute error on the closure probability p
    delta: float
    total_wedges: float = 1.0  # W — for the count-scale tolerance in aux

    def __call__(self, frame: StateFrame):
        tau = frame.num.astype(jnp.float32)
        need = hoeffding_tau_needed(self.eps, self.delta)
        stop = tau >= need
        aux = {"tau": tau, "tau_needed": need,
               "eps_count": jnp.float32(self.eps * self.total_wedges / 3.0)}
        return stop, aux


@dataclasses.dataclass(frozen=True)
class PercolationCondition:
    """Stopping rule for Monte-Carlo s–t reachability (percolation).

    Empirical-Bernstein (Maurer & Pontil) on the reachability indicator
    x ∈ {0,1}: stop when the data-dependent half-width

        sqrt(2·V̂·log(3/δ)/τ) + 3·log(3/δ)/τ  ≤  ε

    For p near 0 or 1 the variance term vanishes and EB stops much earlier
    than Hoeffding — the adaptive win this instance exists to exercise.  A
    static cap ``max_samples`` (the ω analog: the Hoeffding sample bound)
    guarantees termination.  Only the scalar moments ``s1``/``s2`` and ``num``
    enter the verdict; extra frame leaves (e.g. per-vertex hit counts) are
    carried but ignored, and all of these are fully reduced under
    SHARED_FRAME, so the condition is shard-safe.
    """

    eps: float
    delta: float
    max_samples: int = 1 << 20

    def __call__(self, frame: StateFrame):
        tau = jnp.maximum(frame.num.astype(jnp.float32), 2.0)
        mean, half = empirical_bernstein_half_width(
            frame.data["s1"].astype(jnp.float32),
            frame.data["s2"].astype(jnp.float32),
            tau, self.delta)
        eb_ok = jnp.logical_and(frame.num >= 2, half <= self.eps)
        stop = jnp.logical_or(eb_ok, frame.num >= self.max_samples)
        return stop, {"p_hat": mean, "half_width": half, "tau": frame.num}


@dataclasses.dataclass(frozen=True)
class RelativeErrorCondition:
    """Relative-error stopping for weighted-mean estimation (the WRS
    workload): stop once the empirical-Bernstein half-width is below
    ``rtol`` × the running mean estimate,

        sqrt(2·V̂·log(3/δ)/τ) + 3·R·log(3/δ)/τ  ≤  rtol · μ̂

    which gives |μ̂ − μ| ≤ rtol·μ̂ w.p. ≥ 1−δ — the natural guarantee when
    the estimand's magnitude is unknown a priori (H&S weighted sampling).

    ``scale`` undoes integer value quantization: frames carry
    s1 = Σ xq, s2 = Σ xq² with x = xq/scale, so the moments in value units
    are s1/scale and s2/scale².  Only the scalar moments and ``num`` enter
    the verdict (fully reduced under every strategy incl. SHARED_FRAME
    shards ⇒ shard-safe); a static ``max_samples`` cap (the ω analog)
    guarantees termination even for μ near 0.
    """

    rtol: float
    delta: float
    scale: float = 1.0
    value_range: float = 1.0
    min_samples: int = 2
    max_samples: int = 1 << 20

    def __call__(self, frame: StateFrame):
        tau = jnp.maximum(frame.num.astype(jnp.float32), 2.0)
        s1 = frame.data["s1"].astype(jnp.float32) / self.scale
        s2 = frame.data["s2"].astype(jnp.float32) / self.scale ** 2
        mean, half = empirical_bernstein_half_width(
            s1, s2, tau, self.delta, self.value_range)
        rel_ok = half <= self.rtol * jnp.maximum(mean, 1e-12)
        stop = jnp.logical_or(
            jnp.logical_and(frame.num >= self.min_samples, rel_ok),
            frame.num >= self.max_samples)
        return stop, {"mean": mean, "half_width": half, "tau": frame.num}


@dataclasses.dataclass(frozen=True)
class EccentricityGapCondition:
    """Eccentricity-gap stopping for double-sweep diameter estimation.

    Each sample runs a double sweep from a random vertex v: with
    u = argmax dist(v,·), it observes the lower bound ecc(u) ≤ diam and the
    upper bound 2·ecc(v) ≥ diam, and contributes a *certificate* when its
    own gap closes:  2·ecc(v) − ecc(u) ≤ gap  ⇒  diam − ecc(u) ≤ gap.
    Stop once ``min_certs`` certificates have accumulated (the estimate —
    the best lower bound seen — is then within ``gap`` of the true
    diameter), or at the static ``max_samples`` cap.

    The verdict reads only the scalar certificate count and ``num`` (both
    fully reduced under every strategy, SHARED_FRAME shards included ⇒
    shard-safe); the eccentricity histogram the estimate is extracted from
    is carried as a vector leaf but never enters the verdict.
    """

    gap: int = 0
    min_certs: int = 1
    max_samples: int = 1 << 16

    def __call__(self, frame: StateFrame):
        certs = frame.data["cert"]
        stop = jnp.logical_or(certs >= self.min_certs,
                              frame.num >= self.max_samples)
        return stop, {"certs": certs, "tau": frame.num,
                      "gap": jnp.int32(self.gap)}


@dataclasses.dataclass(frozen=True)
class GradVarianceCondition:
    """Adaptive gradient accumulation: stop when the relative standard error
    of the minibatch-mean gradient is below ``rtol``.

    frame.data = {"sum_sq_norm": Σ‖g_i‖², "norm_sum_sq": running ‖Σ g_i‖² is
    not storable incrementally, so we carry Σ g (the gradient itself, which we
    need anyway) separately at the engine level; this condition receives
    {"s1": Σ‖g_i‖ , "s2": Σ‖g_i‖², "dot": Σ gᵢ·ḡ-proxy} reduced to scalars:
    we use the scalar-projection surrogate Var(‖g‖) which upper-bounds the
    directional noise for the step-size purpose (documented simplification).
    """

    rtol: float
    min_samples: int = 2
    max_samples: int = 4096

    def __call__(self, frame: StateFrame):
        tau = jnp.maximum(frame.num.astype(jnp.float32), 1.0)
        s1 = frame.data["s1"].astype(jnp.float32)   # Σ ‖g_i‖
        s2 = frame.data["s2"].astype(jnp.float32)   # Σ ‖g_i‖²
        mean = s1 / tau
        var = jnp.maximum(s2 / tau - mean ** 2, 0.0)
        sem = jnp.sqrt(var / tau)
        rel = sem / jnp.maximum(mean, 1e-12)
        stop = jnp.logical_or(
            jnp.logical_and(frame.num >= self.min_samples, rel <= self.rtol),
            frame.num >= self.max_samples)
        return stop, {"rel_sem": rel, "mean_norm": mean, "tau": frame.num}


def kadabra_omega(eps: float, delta: float, vd_upper: int, c: float = 0.5) -> float:
    """Static maximal sample count ω (Riondato–Kornaropoulos VC bound as used
    by KADABRA's preprocessing): ω = (c/ε²)·(⌊log₂(VD−2)⌋ + 1 + log(1/δ))."""
    import math
    vd = max(int(vd_upper), 4)
    return (c / eps ** 2) * (math.floor(math.log2(vd - 2)) + 1 + math.log(1.0 / delta))

"""Epoch-based adaptive-sampling engine (the paper's Algorithm 2, TPU-native).

One function, :func:`run_worker`, implements the per-worker program for all
five strategies of :class:`~repro.core.frames.FrameStrategy`.  It is written
against the :class:`~repro.core.frames.Collectives` abstraction, so the same
code executes

* sequentially (``sequential_collectives()``, W=1 — the correctness oracle),
* with **virtual workers** under ``vmap(..., axis_name=...)`` (CPU tests and
  the paper-figure benchmarks), and
* with **real devices** under ``shard_map`` on a mesh axis (production).

Strategy semantics (see DESIGN.md §2 for the shared-memory → TPU mapping):

LOCK          reduce + check after *every* sampling round; the decision is a
              data dependency of the next round (original-KADABRA analog).
BARRIER       reduce + check after K rounds; collective still on the critical
              path between epochs ("OpenMP baseline", paper §2.4).
LOCAL_FRAME   the paper's §3.2: the collective consumes the *previous* epoch's
              delta frame, so inside one loop body the reduction of epoch e−1
              and the sampling of epoch e have no data dependency — XLA's
              latency-hiding scheduler can overlap them (async all-reduce on
              TPU).  The stop decision therefore lags one epoch: exactly the
              paper's "termination latency" (App. C.3).
SHARED_FRAME  like LOCAL_FRAME but the reduction is a *reduce-scatter*: each
              worker keeps only its 1/W shard of the consistent state (Θ(n/W)
              memory — the paper's Θ(1)-per-thread trade-off with F = W) and
              evaluates the stopping condition on its shard; the 1-bit
              verdicts are AND-combined with a tiny all-reduce.  Hardware
              accumulation in the reduce-scatter replaces fetch-add.
INDEXED_FRAME deterministic (paper §D.2): frame *m* (= epoch·W + worker) is a
              pure function of ``fold_in(seed, m)`` with a fixed number of
              samples; the checker consumes frames **in index order** and
              stops at the first prefix satisfying the condition ⇒ the result
              is bit-identical for every worker count W.

Consistency (Prop. 1): every state the condition is evaluated on equals
``⊕`` over an *integral* set of per-worker sample prefixes — the proof
obligation ("all stores visible before accumulation") holds by SSA data
dependence: a frame snapshot is a value, not a memory location.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .frames import (Collectives, FrameStrategy, StateFrame, accumulate,
                     combine, sequential_collectives, zeros_like_frame)

PyTree = Any
# sample_fn(key, carry) -> (delta: StateFrame, carry')   — one sampling round
SampleFn = Callable[[jax.Array, PyTree], Tuple[StateFrame, PyTree]]
# check_fn(total: StateFrame) -> (stop: bool scalar, aux pytree)
CheckFn = Callable[[StateFrame], Tuple[jax.Array, PyTree]]


@dataclasses.dataclass(frozen=True)
class EpochConfig:
    strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
    rounds_per_epoch: int = 8     # K sampling rounds between checks (paper's N)
    max_epochs: int = 1_000
    # App. C.3 heuristic: coordinator cadence N₀ = N / W^ξ. Applied via
    # :func:`rounds_for_world` when building per-run configs.
    xi: float = 0.0
    # Execution substrate (core/substrate.py): "sequential" | "vmap" |
    # "shard_map" (or the Substrate enum); None → sequential at W=1, vmap
    # otherwise.  Consumed by substrate.run_on_substrate, not run_worker.
    substrate: "str | None" = None


def rounds_for_world(n_samples_between_checks: int, round_batch: int,
                     world: int, xi: float) -> int:
    """Paper App. C.3: check after N₀ = N / W^ξ samples (per worker)."""
    n0 = n_samples_between_checks / max(1.0, float(world) ** xi)
    return max(1, int(round(n0 / max(1, round_batch))))


class EpochState(NamedTuple):
    key: jax.Array
    carry: PyTree
    total: StateFrame       # consistent reduced state (shard for SHARED)
    pending: StateFrame     # this worker's delta of the epoch just finished
    stop: jax.Array         # bool scalar
    aux: PyTree
    epoch: jax.Array        # int32
    stop_epoch: jax.Array   # epoch at which stop was first seen (for latency stats)


def _sample_epoch(sample_fn: SampleFn, template: PyTree, rounds: int,
                  key: jax.Array, carry: PyTree) -> Tuple[StateFrame, PyTree]:
    """K sampling rounds accumulated into a fresh delta frame."""

    def body(st, k):
        frame, carry = st
        delta, carry = sample_fn(k, carry)
        return (combine(frame, delta), carry), None

    keys = jax.random.split(key, rounds)
    (frame, carry), _ = jax.lax.scan(body, (zeros_like_frame(template), carry), keys)
    return frame, carry


class EpochProgram(NamedTuple):
    """The epoch engine decomposed into single-epoch pieces.

    ``init(key, worker_id)`` builds the primed epoch-0 state; ``body(state,
    worker_id)`` advances exactly one epoch; ``cond(state)`` is the
    keep-running predicate.  ``run_worker`` is literally
    ``while_loop(cond, body, init(...))`` — the serving layer
    (:mod:`repro.serve`) drives the same ``body`` one epoch at a time from
    the host, which is what makes sessions checkpointable and schedulable at
    epoch granularity with *bit-identical* results: the state between epochs
    is a plain pytree (frame snapshots are values, not memory), so
    save → restore → step ≡ step.
    """

    init: Callable[[jax.Array, jax.Array], "EpochState"]
    body: Callable[["EpochState", jax.Array], "EpochState"]
    cond: Callable[["EpochState"], jax.Array]
    cfg: EpochConfig
    fold: Optional[int]


def make_program(
    sample_fn: SampleFn,
    check_fn: CheckFn,
    template: PyTree,
    cfg: EpochConfig,
    colls: Collectives,
    aux_template: Optional[PyTree] = None,
    seed_scalar: Optional[jax.Array] = None,
    fold: Optional[int] = None,
) -> EpochProgram:
    """Build the per-worker epoch program for one strategy.

    ``template`` — pytree with the shape/dtype of ``frame.data`` (for SHARED
    strategies this is the *full* frame; the engine keeps the sharded total).
    ``aux_template`` — shape of check aux (obtained via ``jax.eval_shape`` if
    omitted).  ``seed_scalar`` — required for INDEXED_FRAME (the ``init``/
    ``body`` callables take the worker id as their second argument).

    ``fold = k`` runs **k logical workers per physical worker** (elastic
    re-sharding, :mod:`repro.serve.elastic`): ``state.key`` carries k stacked
    PRNG keys and ``state.carry`` k stacked carries, each epoch samples every
    logical stream and combines the k deltas before the collective.  Because
    ``∘`` is associative/commutative over integer frames, the global epoch
    delta — and hence (τ, estimate) — is bit-identical to the unfolded run
    with W_logical = W_physical · k workers.  Supported for every strategy
    except INDEXED_FRAME (whose frame indices are already W-independent).
    """
    strat = cfg.strategy
    W = colls.world
    if fold is not None and strat == FrameStrategy.INDEXED_FRAME:
        raise ValueError("fold is not supported for INDEXED_FRAME (its "
                         "result is already worker-count independent)")

    F = colls.frame_shards or W
    if aux_template is None:
        zf = zeros_like_frame(template)
        if strat == FrameStrategy.SHARED_FRAME and colls.scatter_frames is not None:
            zf = _shard_zeros(zf, F)
        _, aux_template = jax.eval_shape(check_fn, zf)
    zero_aux = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aux_template)

    if strat == FrameStrategy.SHARED_FRAME:
        total0 = _shard_zeros(zeros_like_frame(template), F)
    else:
        total0 = zeros_like_frame(template)

    def split_keys(key):
        """Per-epoch key evolution — vmapped over the fold's logical streams
        so each stream's split sequence is identical to its unfolded run."""
        if fold is None:
            return _split(key)
        return jax.vmap(_split)(key)

    def sample_epoch(k_epoch, carry, rounds):
        if fold is None:
            return _sample_epoch(sample_fn, template, rounds, k_epoch, carry)
        frames, carry = jax.vmap(
            lambda k, c: _sample_epoch(sample_fn, template, rounds, k, c)
        )(k_epoch, carry)
        return accumulate(frames), carry

    def check_full(total: StateFrame):
        stop, aux = check_fn(total)
        if W > 1:
            # all workers compute the same verdict on replicated data; the
            # psum(min) keeps the verdict well-defined even if reductions are
            # reordered differently per worker (cheap 1-element collective).
            stop = colls.reduce_scalar(stop.astype(jnp.int32)) >= W
        return stop, aux

    def check_sharded(total_shard: StateFrame):
        stop_local, aux = check_fn(total_shard)
        stop = colls.reduce_scalar(stop_local.astype(jnp.int32)) >= W
        return stop, aux

    # ----- LOCK / BARRIER: reduce + check on the critical path -----------
    if strat in (FrameStrategy.LOCK, FrameStrategy.BARRIER):
        rounds = 1 if strat == FrameStrategy.LOCK else cfg.rounds_per_epoch

        def body(st: EpochState, worker_id) -> EpochState:
            k_epoch, key = split_keys(st.key)
            delta, carry = sample_epoch(k_epoch, st.carry, rounds)
            reduced = colls.reduce_frames(delta)          # blocking barrier
            total = combine(st.total, reduced)
            stop, aux = check_full(total)
            e = st.epoch + 1
            return EpochState(key, carry, total, delta, stop, aux, e,
                              jnp.where(stop & ~st.stop, e, st.stop_epoch))

    # ----- LOCAL_FRAME: lagged all-reduce, overlappable ------------------
    elif strat == FrameStrategy.LOCAL_FRAME:

        def body(st: EpochState, worker_id) -> EpochState:
            # (a) fold in the PREVIOUS epoch's deltas — no data dependency on
            # (b), so the all-reduce can overlap the sampling compute.
            reduced = colls.reduce_frames(st.pending)
            total = combine(st.total, reduced)
            stop, aux = check_full(total)
            # (b) sample the current epoch.
            k_epoch, key = split_keys(st.key)
            delta, carry = sample_epoch(k_epoch, st.carry, cfg.rounds_per_epoch)
            e = st.epoch + 1
            return EpochState(key, carry, total, delta, stop, aux, e,
                              jnp.where(stop & ~st.stop, e, st.stop_epoch))

    # ----- SHARED_FRAME: lagged reduce-scatter + 1-bit verdict -----------
    elif strat == FrameStrategy.SHARED_FRAME:
        assert colls.scatter_frames is not None, "SHARED_FRAME needs scatter_frames"

        def body(st: EpochState, worker_id) -> EpochState:
            reduced_shard = colls.scatter_frames(st.pending)
            total = combine(st.total, reduced_shard)
            stop, aux = check_sharded(total)
            k_epoch, key = split_keys(st.key)
            delta, carry = sample_epoch(k_epoch, st.carry, cfg.rounds_per_epoch)
            e = st.epoch + 1
            return EpochState(key, carry, total, delta, stop, aux, e,
                              jnp.where(stop & ~st.stop, e, st.stop_epoch))

    # ----- INDEXED_FRAME: deterministic prefix checking ------------------
    elif strat == FrameStrategy.INDEXED_FRAME:
        assert seed_scalar is not None, "INDEXED_FRAME needs seed_scalar"
        assert colls.all_frames is not None

        def sample_indexed(epoch: jax.Array, worker_id, carry: PyTree):
            m = epoch * W + worker_id          # global frame index
            k = jax.random.fold_in(jax.random.key(0), seed_scalar)
            k = jax.random.fold_in(k, m)
            return _sample_epoch(sample_fn, template, cfg.rounds_per_epoch, k, carry)

        def body(st: EpochState, worker_id) -> EpochState:
            gathered = colls.all_frames(st.pending)   # (W, ...) per-frame deltas

            def prefix_step(acc, j):
                total, stop, aux, stop_epoch = acc
                fj = jax.tree.map(lambda x: x[j], gathered)
                total_j = combine(total, fj)
                s_j, aux_j = check_fn(total_j)
                # freeze at the FIRST stopping prefix (determinism).
                first = s_j & ~stop
                total = jax.tree.map(lambda new, old: jnp.where(stop, old, new),
                                     total_j, total)
                aux = jax.tree.map(lambda new, old: jnp.where(first, new, old),
                                   aux_j, aux)
                stop_epoch = jnp.where(first, st.epoch + 1, stop_epoch)
                return (total, stop | s_j, aux, stop_epoch), None

            (total, stop, aux, stop_epoch), _ = jax.lax.scan(
                prefix_step, (st.total, st.stop, st.aux, st.stop_epoch),
                jnp.arange(W))
            if W > 1:  # verdicts agree (same data), keep them in lockstep
                stop = colls.reduce_scalar(stop.astype(jnp.int32)) >= W
            delta, carry = sample_indexed(st.epoch, worker_id, st.carry)
            return EpochState(st.key, carry, total, delta, stop, aux,
                              st.epoch + 1, stop_epoch)

    else:  # pragma: no cover
        raise ValueError(f"unknown strategy {strat}")

    def cond(st: EpochState):
        return jnp.logical_and(~st.stop, st.epoch < cfg.max_epochs)

    # Epoch 0 produces the first pending frame (there is no SF for epoch 0 —
    # Alg. 2 note on line 9).
    def init(key: jax.Array, worker_id, carry: PyTree = None) -> EpochState:
        state0 = EpochState(
            key=key, carry=carry, total=total0,
            pending=zeros_like_frame(template),
            stop=jnp.zeros((), bool), aux=zero_aux,
            epoch=jnp.zeros((), jnp.int32), stop_epoch=jnp.zeros((), jnp.int32))
        if strat == FrameStrategy.INDEXED_FRAME:
            # NB: body samples frame for st.epoch (already advanced), so
            # indexed frame indices stay contiguous: 0·W+wid, 1·W+wid, ...
            delta, carry0 = sample_indexed(jnp.zeros((), jnp.int32),
                                           worker_id, state0.carry)
            return state0._replace(pending=delta, carry=carry0,
                                   epoch=jnp.ones((), jnp.int32))
        if strat in (FrameStrategy.LOCAL_FRAME, FrameStrategy.SHARED_FRAME):
            k0, key2 = split_keys(state0.key)
            delta0, carry0 = sample_epoch(k0, state0.carry,
                                          cfg.rounds_per_epoch)
            return state0._replace(key=key2, carry=carry0, pending=delta0,
                                   epoch=jnp.ones((), jnp.int32))
        return state0

    return EpochProgram(init=init, body=body, cond=cond, cfg=cfg, fold=fold)


def run_worker(
    sample_fn: SampleFn,
    check_fn: CheckFn,
    template: PyTree,
    init_carry: PyTree,
    key: jax.Array,
    cfg: EpochConfig,
    colls: Optional[Collectives] = None,
    aux_template: Optional[PyTree] = None,
    seed_scalar: Optional[jax.Array] = None,
    worker_id: Optional[jax.Array] = None,
) -> EpochState:
    """Run the adaptive-sampling loop for one (SPMD) worker to completion.

    Convenience wrapper: ``while_loop`` over :func:`make_program`'s pieces.
    ``seed_scalar``/``worker_id`` — required for INDEXED_FRAME.
    """
    colls = colls or sequential_collectives()
    if cfg.strategy == FrameStrategy.INDEXED_FRAME:
        assert worker_id is not None, "INDEXED_FRAME needs worker_id"
    wid = worker_id if worker_id is not None else jnp.zeros((), jnp.int32)
    prog = make_program(sample_fn, check_fn, template, cfg, colls,
                        aux_template=aux_template, seed_scalar=seed_scalar)
    state0 = prog.init(key, wid, init_carry)
    return jax.lax.while_loop(prog.cond, lambda st: prog.body(st, wid), state0)


def _split(key):
    k1, k2 = jax.random.split(key)
    return k1, k2


def _shard_zeros(frame: StateFrame, world: int) -> StateFrame:
    """Zero frame shaped like this worker's 1/W reduce-scatter shard."""
    def shard(x):
        if x.ndim == 0:
            return x
        assert x.shape[0] % world == 0, (
            f"SHARED_FRAME needs leading dim divisible by W={world}; pad the "
            f"frame (got {x.shape}) — see frames.shard_frame_pad")
        return jnp.zeros((x.shape[0] // world,) + x.shape[1:], x.dtype)
    return StateFrame(num=frame.num, data=jax.tree.map(shard, frame.data))


# ---------------------------------------------------------------------------
# Virtual-worker wrapper: simulate W workers on one device with vmap.  This is
# how tests and the paper-figure benchmarks execute the engine on CPU, and it
# is semantically identical to shard_map over a mesh axis of size W.
# ---------------------------------------------------------------------------

AXIS = "workers"


def run_virtual(sample_fn: SampleFn, check_fn: CheckFn, template: PyTree,
                init_carry: PyTree, seed: int, world: int, cfg: EpochConfig,
                frame_shards: int = 0) -> EpochState:
    from .frames import axis_collectives
    colls = axis_collectives(AXIS, world, frame_shards=frame_shards)

    def per_worker(key, wid):
        return run_worker(sample_fn, check_fn, template, init_carry, key, cfg,
                          colls=colls,
                          seed_scalar=jnp.asarray(seed, jnp.uint32),
                          worker_id=wid)

    keys = jax.random.split(jax.random.key(seed), world)
    wids = jnp.arange(world, dtype=jnp.int32)
    return jax.vmap(per_worker, axis_name=AXIS)(keys, wids)


def run_sharded(sample_fn: SampleFn, check_fn: CheckFn, template: PyTree,
                init_carry: PyTree, seed: int, mesh, axis: str,
                cfg: EpochConfig, frame_shards: int = 0) -> EpochState:
    """Run the engine over a real mesh axis with shard_map (production path).

    Every leaf of ``init_carry``/``template`` is treated as replicated;
    sampling randomness is decorrelated per worker via key splitting (or frame
    indices for INDEXED_FRAME).  Outputs are stacked per worker along a new
    leading axis of size W (scalars become ``(W,)``; replicated quantities
    like ``total``/``stop`` repeat identically — callers index ``[0]``).

    Collectives are built with ``grouped=True``: the SHARED_FRAME F < W path
    runs the paper's grouped reduce-scatter + cross-group all-reduce via
    ``axis_index_groups`` (real collectives, no psum+slice fallback).
    """
    from jax.sharding import PartitionSpec as P
    from .compat import shard_map
    from .frames import axis_collectives

    world = mesh.shape[axis]
    colls = axis_collectives(axis, world, frame_shards=frame_shards,
                             grouped=True)

    def per_worker(keys, wids):
        st = run_worker(sample_fn, check_fn, template, init_carry,
                        keys[0], cfg, colls=colls,
                        seed_scalar=jnp.asarray(seed, jnp.uint32),
                        worker_id=wids[0])
        # add a per-worker leading dim so every leaf can carry P(axis)
        return jax.tree.map(lambda x: jnp.asarray(x)[None], st)

    keys = jax.random.split(jax.random.key(seed), world)
    wids = jnp.arange(world, dtype=jnp.int32)
    fn = shard_map(per_worker, mesh=mesh,
                   in_specs=(P(axis), P(axis)),
                   out_specs=P(axis),
                   check_vma=False)
    return fn(keys, wids)

"""Cross-strategy conformance harness.

Runs one registered ADS instance under every (or a chosen subset of)
:class:`~repro.core.frames.FrameStrategy` × virtual world size and asserts
the paper's invariants, turning "does strategy/kernel change X break any
workload?" into a one-line check:

    report = run_conformance("triangles")
    assert report.ok, report.summary()

Invariants checked per cell (strategy, W):

1. **Termination** — the engine stops before ``max_epochs`` (Alg. 1 must
   terminate once the static ω-style bound holds).
2. **Sample-count consistency** (Prop. 1) — the checked state is ``⊕`` over
   an *integral* set of per-worker sample prefixes: ``total.num`` is a whole
   number of epoch frames (× all W workers for the frame strategies whose
   reductions always fold complete epochs).
3. **(ε, δ) accuracy** — the estimate agrees with the exact oracle within
   the instance tolerance ε and with the W=1 sequential oracle run within
   2ε (fixed seeds keep this deterministic).

Cross-cell invariants:

4. **INDEXED_FRAME determinism** (§D.2) — bit-identical ``total`` (num and
   trimmed data) for every W.
5. **SHARED_FRAME reassembly** (§3.2) — the reduce-scattered shards, glued
   back together, equal the replicated LOCAL_FRAME total at the same
   (seed, W) — hardware reduce-scatter ≡ fetch-add.

Substrate equivalence (:func:`run_substrate_equivalence`): every
(strategy × W × F) cell must produce **bit-identical** τ, trimmed data, and
estimate under the sequential / vmap / shard_map execution substrates
(:mod:`repro.core.substrate`), so collectives changes — in particular the
grouped F < W reduce-scatter that only exists under shard_map — can never
silently diverge from the simulated semantics the rest of the suite runs on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .frames import FrameStrategy
from .instances import AdaptiveInstance, get_instance, run_instance
from .substrate import Substrate, unavailable_reason

DEFAULT_WORLDS = (1, 2, 4)
EQUIVALENCE_WORLDS = (1, 2, 4, 8)


@dataclasses.dataclass
class CellResult:
    instance: str
    strategy: FrameStrategy
    world: int
    num: int
    stopped: bool
    err_oracle: float
    err_sequential: float
    failures: List[str]
    estimate: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))

    @property
    def ok(self) -> bool:
        return not self.failures


@dataclasses.dataclass
class ConformanceReport:
    instance: str
    cells: List[CellResult]
    cross_failures: List[str]

    @property
    def ok(self) -> bool:
        return not self.cross_failures and all(c.ok for c in self.cells)

    @property
    def failures(self) -> List[str]:
        out = [f for c in self.cells for f in c.failures]
        return out + list(self.cross_failures)

    def summary(self) -> str:
        lines = [f"conformance[{self.instance}]: "
                 f"{sum(c.ok for c in self.cells)}/{len(self.cells)} cells ok"]
        for c in self.cells:
            tag = "ok " if c.ok else "FAIL"
            lines.append(f"  {tag} {c.strategy.name:13s} W={c.world} "
                         f"τ={c.num:6d} err={c.err_oracle:.4f}"
                         + ("" if c.ok else f"  <- {'; '.join(c.failures)}"))
        lines += [f"  CROSS FAIL: {f}" for f in self.cross_failures]
        return "\n".join(lines)


def _tree_equal(a, b) -> bool:
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def run_conformance(instance: "str | AdaptiveInstance", *,
                    strategies: Optional[Sequence[FrameStrategy]] = None,
                    worlds: Sequence[int] = DEFAULT_WORLDS,
                    seed: int = 0) -> ConformanceReport:
    """Sweep one instance over strategies × worlds and check all invariants."""
    inst = get_instance(instance) if isinstance(instance, str) else instance
    strategies = list(strategies) if strategies is not None \
        else list(FrameStrategy)

    # W=1 sequential oracle: BARRIER at W=1 checks after every epoch — the
    # reference Algorithm 1 execution.
    ref_est, ref_res, _ = run_instance(inst, strategy=FrameStrategy.BARRIER,
                                       world=1, seed=seed)

    cells: List[CellResult] = []
    indexed: Dict[int, Tuple[int, object]] = {}
    local: Dict[int, Tuple[int, object]] = {}
    shared: Dict[int, Tuple[int, object]] = {}

    for strat in strategies:
        for world in worlds:
            est, res, built = run_instance(inst, strategy=strat, world=world,
                                           seed=seed)
            failures: List[str] = []
            where = f"{built.name}/{strat.name}/W={world}"

            if not res.stopped:
                failures.append(f"{where}: did not stop "
                                f"within {built.max_epochs} epochs")

            # Prop. 1: τ = Σ over integral per-worker frame prefixes.
            spf = built.samples_per_round * (
                1 if strat == FrameStrategy.LOCK else built.rounds_per_epoch)
            unit = spf if strat == FrameStrategy.INDEXED_FRAME \
                else spf * world
            if res.num <= 0 or res.num % unit != 0:
                failures.append(f"{where}: τ={res.num} is not a whole number "
                                f"of {unit}-sample frame sets")

            err_o = float(np.max(np.abs(est - built.oracle)))
            if err_o > built.eps:
                failures.append(f"{where}: oracle error {err_o:.4f} "
                                f"> ε={built.eps:.4f}")
            err_s = float(np.max(np.abs(est - ref_est)))
            if err_s > 2.0 * built.eps:
                failures.append(f"{where}: deviates from W=1 sequential "
                                f"oracle by {err_s:.4f} > 2ε")

            trimmed = built.trim(res.data)
            if strat == FrameStrategy.INDEXED_FRAME:
                indexed[world] = (res.num, trimmed)
            elif strat == FrameStrategy.LOCAL_FRAME:
                local[world] = (res.num, trimmed)
            elif strat == FrameStrategy.SHARED_FRAME:
                shared[world] = (res.num, trimmed)

            cells.append(CellResult(
                instance=built.name, strategy=strat, world=world,
                num=res.num, stopped=res.stopped, err_oracle=err_o,
                err_sequential=err_s, failures=failures, estimate=est))

    cross: List[str] = []
    if len(indexed) > 1:
        w0 = min(indexed)
        num0, data0 = indexed[w0]
        for w, (num, data) in sorted(indexed.items()):
            if num != num0:
                cross.append(f"INDEXED_FRAME τ differs across worlds: "
                             f"W={w0}→{num0}, W={w}→{num}")
            if not _tree_equal(data, data0):
                cross.append(f"INDEXED_FRAME data differs: W={w0} vs W={w}")
    for w in sorted(set(local) & set(shared)):
        num_l, data_l = local[w]
        num_s, data_s = shared[w]
        if num_l != num_s:
            cross.append(f"W={w}: SHARED τ={num_s} ≠ LOCAL τ={num_l}")
        if not _tree_equal(data_l, data_s):
            cross.append(f"W={w}: SHARED shard reassembly ≠ LOCAL total")

    name = inst.name if not isinstance(instance, str) else instance
    return ConformanceReport(instance=name, cells=cells, cross_failures=cross)


def run_all(*, strategies: Optional[Sequence[FrameStrategy]] = None,
            worlds: Sequence[int] = DEFAULT_WORLDS,
            seed: int = 0) -> Dict[str, ConformanceReport]:
    """Conformance across every registered instance.

    ``seed`` flows into every cell *and* the W=1 sequential reference run of
    each per-instance sweep, so a multi-seed certification is simply
    ``{s: run_all(seed=s) for s in seeds}`` — no cell ever silently runs at
    a default seed.
    """
    from .instances import available_instances
    return {name: run_conformance(name, strategies=strategies, worlds=worlds,
                                  seed=seed)
            for name in available_instances()}


# ---------------------------------------------------------------------------
# Substrate equivalence: sequential / vmap / shard_map must agree bit-for-bit.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SubstrateCell:
    """One (strategy, W, F) cell compared across execution substrates."""

    instance: str
    strategy: FrameStrategy
    world: int
    frame_shards: int             # paper's F (0 → W)
    num: int                      # reference (vmap) τ
    ran: List[str]                # substrate values that executed
    skipped: Dict[str, str]       # substrate value -> why it could not run
    failures: List[str]

    @property
    def ok(self) -> bool:
        return not self.failures

    @property
    def compared(self) -> int:
        """How many substrates were actually cross-checked against vmap."""
        return max(0, len(self.ran) - 1)


@dataclasses.dataclass
class SubstrateReport:
    instance: str
    cells: List[SubstrateCell]

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.cells)

    @property
    def failures(self) -> List[str]:
        return [f for c in self.cells for f in c.failures]

    def summary(self) -> str:
        lines = [f"substrate-equivalence[{self.instance}]: "
                 f"{sum(c.ok for c in self.cells)}/{len(self.cells)} cells ok"]
        for c in self.cells:
            tag = "ok " if c.ok else "FAIL"
            F = c.frame_shards or c.world
            lines.append(
                f"  {tag} {c.strategy.name:13s} W={c.world} F={F} "
                f"τ={c.num:6d} ran={','.join(c.ran)}"
                + (f" skipped={sorted(c.skipped)}" if c.skipped else "")
                + ("" if c.ok else f"  <- {'; '.join(c.failures)}"))
        return "\n".join(lines)


def equivalence_grid(worlds: Sequence[int] = EQUIVALENCE_WORLDS,
                     strategies: Optional[Sequence[FrameStrategy]] = None,
                     ) -> List[Tuple[FrameStrategy, int, int]]:
    """The (strategy, W, F) cells of the substrate-equivalence suite: the
    full strategy × W grid at F = W, plus the SHARED_FRAME F = W/2 cells
    that exercise the grouped reduce-scatter + cross-group all-reduce."""
    strategies = list(strategies) if strategies is not None \
        else list(FrameStrategy)
    cells = [(s, w, 0) for s in strategies for w in worlds]
    if FrameStrategy.SHARED_FRAME in strategies:
        cells += [(FrameStrategy.SHARED_FRAME, w, w // 2)
                  for w in worlds if w >= 2]
    return cells


def run_substrate_equivalence(
        instance: "str | AdaptiveInstance", *,
        strategies: Optional[Sequence[FrameStrategy]] = None,
        worlds: Sequence[int] = EQUIVALENCE_WORLDS,
        substrates: Optional[Sequence[Substrate]] = None,
        seed: int = 0,
        require_all: bool = False) -> SubstrateReport:
    """Run one instance's (strategy × W × F) grid on every substrate that can
    execute here and demand bit-identical τ, trimmed data, and estimate.

    vmap is the reference substrate (always available; it is what the rest of
    the test suite certifies).  The sequential oracle joins at W=1; shard_map
    joins wherever ``len(jax.devices()) ≥ W``.  A substrate that cannot run
    is recorded in ``cell.skipped`` — or failed outright with
    ``require_all=True`` (the CI substrate job sets it so a mis-provisioned
    runner cannot silently skip the whole point of the suite).
    """
    inst = get_instance(instance) if isinstance(instance, str) else instance
    subs = list(substrates) if substrates is not None else list(Substrate)

    cells: List[SubstrateCell] = []
    for strat, world, F in equivalence_grid(worlds, strategies):
        runs: Dict[str, Tuple[int, object, np.ndarray]] = {}
        skipped: Dict[str, str] = {}
        failures: List[str] = []
        where = f"{inst.name}/{strat.name}/W={world}/F={F or world}"
        for sub in subs:
            reason = unavailable_reason(sub, world)
            if reason is not None:
                skipped[sub.value] = reason
                if require_all and sub != Substrate.SEQUENTIAL:
                    failures.append(f"{where}: required substrate "
                                    f"{sub.value} unavailable: {reason}")
                continue
            est, res, built = run_instance(
                inst, strategy=strat, world=world, seed=seed,
                substrate=sub.value, frame_shards=F)
            runs[sub.value] = (res.num, built.trim(res.data), est)

        ref_key = Substrate.VMAP.value
        if ref_key not in runs:
            failures.append(f"{where}: reference substrate {ref_key} did "
                            f"not run")
            num0 = -1
        else:
            num0, data0, est0 = runs[ref_key]
            for key, (num, data, est) in runs.items():
                if key == ref_key:
                    continue
                if num != num0:
                    failures.append(f"{where}: τ differs — {key}={num}, "
                                    f"{ref_key}={num0}")
                if not _tree_equal(data, data0):
                    failures.append(f"{where}: trimmed data differs — "
                                    f"{key} vs {ref_key}")
                if not np.array_equal(est, est0):
                    failures.append(f"{where}: estimate differs — "
                                    f"{key} vs {ref_key}")

        cells.append(SubstrateCell(
            instance=inst.name, strategy=strat, world=world, frame_shards=F,
            num=num0, ran=sorted(runs), skipped=skipped, failures=failures))
    return SubstrateReport(instance=inst.name, cells=cells)

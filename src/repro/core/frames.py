"""State frames (SFs) — the paper's core data structure, as JAX pytrees.

A state frame holds the sampling state of Algorithm 1:

    frame.num   — number of samples accumulated (scalar, int32/int64-as-float ok)
    frame.data  — the sampled data (any pytree of arrays; ``n`` = its total size)

The accumulation operator ``∘`` of the paper must be associative; here it is
elementwise ``+`` over the pytree (sufficient for KADABRA's per-vertex counts
and for gradient/metric accumulation), but :func:`combine` accepts a custom
monoid for exotic ADS instances.

Frame *strategies* (paper §3.2, §D.2) are represented by
:class:`FrameStrategy`; the epoch engine in ``core/epoch.py`` interprets them.

Hardware adaptation (see DESIGN.md §2): the paper's per-thread SFs published
via store-release become per-device *delta frames* combined with a lagged
collective.  Equivalence: with cumulative per-thread frames the checked state
is ``⊕_t cum_t(e)``; with delta frames and a running total it is
``R_e = R_{e-1} ∘ (⊕_t Δ_{t,e})`` — identical by associativity of ``∘``.
"""

from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StateFrame:
    """One state frame (paper Fig. 1a). ``epoch`` is static metadata on the
    host side; inside jitted code it is a traced scalar."""

    num: jax.Array  # scalar — number of samples in this frame
    data: PyTree    # the sampled data ("n" elements in total)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        leaves = jax.tree_util.tree_leaves(self.data)
        n = sum(int(x.size) for x in leaves if hasattr(x, "size"))
        return f"StateFrame(num={self.num!r}, n={n})"


class FrameStrategy(enum.Enum):
    """Parallelization strategies from the paper (plus the two baselines)."""

    LOCK = "lock"            # original-KADABRA analog: reduce+check every round
    BARRIER = "barrier"      # "OpenMP baseline": reduce+check every N samples,
                             # collective on the critical path
    LOCAL_FRAME = "local"    # per-device frames, lagged all-reduce (paper §3.2)
    SHARED_FRAME = "shared"  # sharded frames, reduce-scatter accumulation
    INDEXED_FRAME = "indexed"  # deterministic (paper §D.2)


def zeros_like_frame(template: PyTree) -> StateFrame:
    """A fresh (empty) frame for the given data template — Alg. 2 line 12."""
    data = jax.tree.map(lambda x: jnp.zeros(jnp.shape(x), jnp.result_type(x)), template)
    return StateFrame(num=jnp.zeros((), jnp.int32), data=data)


def combine(a: StateFrame, b: StateFrame,
            op: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add) -> StateFrame:
    """The associative ``∘`` of Algorithm 1 lifted to frames."""
    return StateFrame(num=a.num + b.num, data=jax.tree.map(op, a.data, b.data))


def accumulate(frames: StateFrame, axis: int = 0) -> StateFrame:
    """Accumulate a stacked batch of frames along ``axis`` (Alg. 2 line 27).

    This is the Θ(T·n) hot spot of CHECKFRAMES; on TPU it is served by the
    ``frame_accum`` Pallas kernel (kernels/frame_accum) — this pure-jnp form is
    its oracle and the XLA lowering path.
    """
    return StateFrame(
        num=jnp.sum(frames.num, axis=axis),
        data=jax.tree.map(lambda x: jnp.sum(x, axis=axis), frames.data),
    )


def scale(frame: StateFrame, s: jax.Array) -> StateFrame:
    return StateFrame(num=frame.num, data=jax.tree.map(lambda x: x * s, frame.data))


# ---------------------------------------------------------------------------
# Collective interfaces.  The epoch engine is written against this tiny
# abstraction so the same code runs (a) under vmap with "virtual workers"
# (tests / CPU benchmarks), (b) under shard_map on a real mesh axis, and
# (c) sequentially (W=1 oracle).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Collectives:
    """How frames of all workers are combined at an epoch boundary.

    ``reduce_frames``  — full combine (local-frame): every worker ends up with
                         ``⊕_t Δ_t``  (paper: thread-0 accumulation loop).
    ``scatter_frames`` — sharded combine (shared-frame): worker ``i`` ends up
                         with shard ``i`` of ``⊕_t Δ_t`` (replaces fetch-add).
    ``all_frames``     — gather the per-worker deltas (indexed-frame prefix
                         checks).
    ``reduce_scalar``  — combine a scalar verdict/statistic across workers.
    """

    reduce_frames: Callable[[StateFrame], StateFrame]
    reduce_scalar: Callable[[jax.Array], jax.Array]
    all_frames: Optional[Callable[[StateFrame], StateFrame]] = None
    scatter_frames: Optional[Callable[[StateFrame], StateFrame]] = None
    axis_name: Optional[str] = None
    world: int = 1
    frame_shards: int = 0   # paper's F (0 → world)


def sequential_collectives() -> Collectives:
    """W=1: everything is the identity."""
    def ident(x):
        return x
    return Collectives(reduce_frames=ident, reduce_scalar=ident,
                       all_frames=lambda f: jax.tree.map(lambda x: x[None], f),
                       scatter_frames=ident, world=1)


def shard_groups(world: int, frame_shards: int) -> tuple:
    """The two ``axis_index_groups`` of the paper's grouped SHARED_FRAME
    reduction (§3.2, Fig. 3b) for F = ``frame_shards`` < W = ``world``.

    ``within``  — world/F groups of F consecutive workers; the reduce-scatter
                  runs inside each, leaving worker g·F+i with shard i of the
                  *group* sum.
    ``across``  — F groups of world/F workers that hold the same shard index;
                  the all-reduce across each sums the n/F group partials into
                  the global shard.
    """
    F = frame_shards
    assert 1 <= F <= world and world % F == 0, (world, F)
    within = [[g * F + i for i in range(F)] for g in range(world // F)]
    across = [[g * F + i for g in range(world // F)] for i in range(F)]
    return within, across


def axis_collectives(axis_name: str, world: int,
                     frame_shards: int = 0, *,
                     grouped: bool = False) -> Collectives:
    """Collectives over a named mapped axis (vmap(axis_name=...) or shard_map).

    Under ``shard_map`` on a mesh axis these lower to real all-reduce /
    reduce-scatter / all-gather collectives; under ``vmap`` they simulate the
    same semantics for W virtual workers on one device.

    ``frame_shards`` (= the paper's **F**, §3.2/Fig. 3b): how many shards the
    SHARED_FRAME state is split into.  F = world → a plain reduce-scatter
    (minimum memory).  F < world → workers are grouped into world/F redundant
    groups: reduce-scatter *within* a group of F, then an all-reduce *across*
    the groups of the per-shard partials — memory n/F per worker, bandwidth
    split between the two phases, mirroring the paper's F trade-off.

    ``grouped`` selects the implementation of the F < world case:

    * ``False`` (vmap / virtual workers) — reference psum+slice.  vmap does
      not support ``axis_index_groups``, so the full sum is materialized and
      each worker slices its shard; semantically identical, memory Θ(n).
    * ``True`` (shard_map on a real mesh axis) — the paper's true grouped
      form: ``psum_scatter`` *within* each group of F via
      ``axis_index_groups``, then a cross-group ``psum`` of the n/F partials.
      No worker ever materializes the full sum.

    Both forms leave worker g·F+i holding shard i of the GLOBAL sum, so
    results are bit-identical for the integer frames the engine uses.
    """

    def reduce_frames(f: StateFrame) -> StateFrame:
        return jax.tree.map(partial(jax.lax.psum, axis_name=axis_name), f)

    def reduce_scalar(x: jax.Array) -> jax.Array:
        return jax.lax.psum(x, axis_name=axis_name)

    def all_frames(f: StateFrame) -> StateFrame:
        return jax.tree.map(
            partial(jax.lax.all_gather, axis_name=axis_name, axis=0), f)

    F = frame_shards or world
    assert world % F == 0 and F <= world, (world, F)
    within, across = shard_groups(world, F) if F < world else (None, None)

    def scatter_frames(f: StateFrame) -> StateFrame:
        # reduce-scatter: each worker keeps its 1/F shard of the sum.
        # psum_scatter requires the leading dim divisible by F; frames used
        # with SHARED_FRAME must be padded accordingly (see shard_frame_pad).
        def rs(x):
            if x.ndim == 0:  # scalars (num) are fully reduced
                return jax.lax.psum(x, axis_name=axis_name)
            if F == world:
                return jax.lax.psum_scatter(x, axis_name=axis_name,
                                            tiled=True)
            if grouped:
                # F < W, true grouped form (shard_map): reduce-scatter the
                # group of F, then all-reduce the n/F partials across the
                # world/F groups.  Peak per-worker memory stays Θ(n/F).
                part = jax.lax.psum_scatter(x, axis_name=axis_name,
                                            tiled=True,
                                            axis_index_groups=within)
                return jax.lax.psum(part, axis_name=axis_name,
                                    axis_index_groups=across)
            # F < W reference form (vmap: axis_index_groups unsupported):
            # psum then slice — worker g·F+i holds shard i of the global sum.
            total = jax.lax.psum(x, axis_name=axis_name)
            wid = jax.lax.axis_index(axis_name)
            shard_len = x.shape[0] // F
            start = (wid % F) * shard_len
            return jax.lax.dynamic_slice_in_dim(total, start, shard_len,
                                                axis=0)
        return StateFrame(num=jax.lax.psum(f.num, axis_name=axis_name),
                          data=jax.tree.map(rs, f.data))

    return Collectives(reduce_frames=reduce_frames, reduce_scalar=reduce_scalar,
                       all_frames=all_frames, scatter_frames=scatter_frames,
                       axis_name=axis_name, world=world, frame_shards=F)


def shard_frame_pad(n: int, world: int) -> int:
    """Padded frame length so a length-``n`` data vector reduce-scatters
    evenly over ``world`` workers (shared-frame)."""
    return ((n + world - 1) // world) * world

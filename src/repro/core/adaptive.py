"""Generic adaptive-sampling driver — the public API of the paper's
Algorithm 1/2 (convenience facade over :mod:`repro.core.epoch`).

    result = run_adaptive(
        sample_fn,                # SAMPLE(): key, carry -> (StateFrame, carry)
        check_fn,                 # CHECKFORSTOP(): StateFrame -> (bool, aux)
        template=jnp.zeros(n),    # shape of frame.data
        strategy="local",         # lock|barrier|local|shared|indexed
        world=8,                  # parallel workers (vmap-virtual or mesh)
        rounds_per_epoch=4,       # paper's N (App. C.2), in rounds
        xi=1.33,                  # App. C.3 cadence heuristic
    )

Returns an :class:`AdaptiveResult` with the consistent final state, the
estimate count τ, and termination statistics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .epoch import EpochConfig, EpochState, rounds_for_world, run_sharded, \
    run_virtual, run_worker
from .frames import FrameStrategy, sequential_collectives

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdaptiveResult:
    data: PyTree            # consistent accumulated data (full, unsharded)
                            # — numpy leaves, same treedef as the template
    num: int                # τ — samples in the checked state
    stopped: bool
    epochs: int
    stop_epoch: int
    aux: PyTree
    state: EpochState


def run_adaptive(sample_fn, check_fn, template: PyTree, *,
                 strategy: str | FrameStrategy = "local",
                 world: int = 1, seed: int = 0, rounds_per_epoch: int = 4,
                 max_epochs: int = 10_000, xi: float = 0.0,
                 round_batch: int = 1, init_carry: PyTree = None,
                 mesh=None, mesh_axis: Optional[str] = None,
                 frame_shards: int = 0) -> AdaptiveResult:
    strat = FrameStrategy(strategy) if isinstance(strategy, str) else strategy
    if mesh is not None and mesh_axis is not None:
        world = mesh.shape[mesh_axis]  # outputs are stacked per worker
    rounds = rounds_for_world(rounds_per_epoch * round_batch, round_batch,
                              world, xi) if xi else rounds_per_epoch
    cfg = EpochConfig(strategy=strat, rounds_per_epoch=rounds,
                      max_epochs=max_epochs, xi=xi)
    if mesh is not None and mesh_axis is not None:
        st = run_sharded(sample_fn, check_fn, template, init_carry, seed,
                         mesh, mesh_axis, cfg, frame_shards=frame_shards)
    elif world == 1:
        st = run_worker(sample_fn, check_fn, template, init_carry,
                        jax.random.key(seed), cfg,
                        colls=sequential_collectives(),
                        seed_scalar=jnp.asarray(seed, jnp.uint32),
                        worker_id=jnp.int32(0))
    else:
        st = run_virtual(sample_fn, check_fn, template, init_carry, seed,
                         world, cfg, frame_shards=frame_shards)

    # run_virtual/run_sharded stack outputs per worker (even for W=1 meshes);
    # only the W=1 run_worker path returns unstacked leaves.
    stacked = (mesh is not None and mesh_axis is not None) or world > 1

    def first(x):
        a = np.asarray(x)
        return a[0] if (stacked and a.ndim >= 1 and a.shape[0] == world) \
            else a

    if strat == FrameStrategy.SHARED_FRAME and stacked:
        # Reassemble the reduce-scattered total: worker i holds shard i of
        # ⊕ Δ (with F < W, group 0 — workers 0..F−1 — holds one full copy).
        F = frame_shards or world

        def reassemble(x):
            a = np.asarray(x)
            if a.ndim <= 1:  # per-worker scalar leaf — fully reduced
                return a[0] if a.ndim == 1 else a
            return a[:F].reshape(F * a.shape[1], *a.shape[2:])

        data = jax.tree.map(reassemble, st.total.data)
    else:
        data = jax.tree.map(first, st.total.data)
    return AdaptiveResult(
        data=data, num=int(first(st.total.num)),
        stopped=bool(first(st.stop)), epochs=int(first(st.epoch)),
        stop_epoch=int(first(st.stop_epoch)),
        aux=jax.tree.map(first, st.aux), state=st)

"""Generic adaptive-sampling driver — the public API of the paper's
Algorithm 1/2 (convenience facade over :mod:`repro.core.epoch`).

    result = run_adaptive(
        sample_fn,                # SAMPLE(): key, carry -> (StateFrame, carry)
        check_fn,                 # CHECKFORSTOP(): StateFrame -> (bool, aux)
        template=jnp.zeros(n),    # shape of frame.data
        strategy="local",         # lock|barrier|local|shared|indexed
        world=8,                  # parallel workers
        substrate="shard_map",    # sequential|vmap|shard_map (core/substrate)
        rounds_per_epoch=4,       # paper's N (App. C.2), in rounds
        xi=1.33,                  # App. C.3 cadence heuristic
    )

Returns an :class:`AdaptiveResult` with the consistent final state, the
estimate count τ, and termination statistics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from .epoch import EpochConfig, EpochState, rounds_for_world
from .frames import FrameStrategy
from .substrate import Substrate, resolve_substrate, run_on_substrate

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdaptiveResult:
    data: PyTree            # consistent accumulated data (full, unsharded)
                            # — numpy leaves, same treedef as the template
    num: int                # τ — samples in the checked state
    stopped: bool
    epochs: int
    stop_epoch: int
    aux: PyTree
    state: EpochState


def reassemble_shared(x, world: int, frame_shards: int):
    """Glue the per-worker reduce-scatter shards of one SHARED_FRAME leaf
    (stacked ``(W, n/F, ...)``) back into the full ``(n, ...)`` vector.

    With F < W the W/F groups hold redundant copies of every shard; shard i
    is gathered from whichever group owns that copy (round-robin over the
    groups, so no single group is assumed authoritative) after verifying the
    redundant copies agree — a cross-group mismatch means the grouped
    reduction itself diverged and is raised, never silently papered over.
    """
    a = np.asarray(x)
    if a.ndim <= 1:  # per-worker scalar leaf (num) — fully reduced
        return a[0] if a.ndim == 1 else a
    F = frame_shards or world
    groups = world // F
    shards = a.reshape(groups, F, *a.shape[1:])
    for g in range(1, groups):
        if not np.array_equal(shards[g], shards[0]):
            raise AssertionError(
                f"SHARED_FRAME redundant groups disagree (group {g} vs 0, "
                f"W={world}, F={F}) — grouped reduce-scatter diverged")
    picked = np.stack([shards[i % groups, i] for i in range(F)])
    return picked.reshape(F * a.shape[1], *a.shape[2:])


def run_adaptive(sample_fn, check_fn, template: PyTree, *,
                 strategy: str | FrameStrategy = "local",
                 world: int = 1, seed: int = 0, rounds_per_epoch: int = 4,
                 max_epochs: int = 10_000, xi: float = 0.0,
                 round_batch: int = 1, init_carry: PyTree = None,
                 substrate: "str | Substrate | None" = None,
                 mesh=None, mesh_axis: Optional[str] = None,
                 frame_shards: int = 0) -> AdaptiveResult:
    strat = FrameStrategy(strategy) if isinstance(strategy, str) else strategy
    if mesh is not None and mesh_axis is not None:
        # explicit mesh implies the shard_map substrate on that mesh
        world = mesh.shape[mesh_axis]
        substrate = Substrate.SHARD_MAP
    rounds = rounds_for_world(rounds_per_epoch * round_batch, round_batch,
                              world, xi) if xi else rounds_per_epoch
    sub = resolve_substrate(substrate, world)
    cfg = EpochConfig(strategy=strat, rounds_per_epoch=rounds,
                      max_epochs=max_epochs, xi=xi, substrate=sub.value)
    st = run_on_substrate(sample_fn, check_fn, template, init_carry, seed,
                          world, cfg, substrate=sub,
                          frame_shards=frame_shards, mesh=mesh,
                          mesh_axis=mesh_axis)
    return result_from_state(st, strategy=strat, world=world,
                             frame_shards=frame_shards)


def result_from_state(st: EpochState, *, strategy: FrameStrategy, world: int,
                      frame_shards: int = 0) -> AdaptiveResult:
    """Extract the consistent :class:`AdaptiveResult` from a per-worker
    stacked :class:`EpochState` (every substrate — and the serving layer's
    epoch stepper — returns this layout: leading dim ``world`` on each leaf).

    SHARED_FRAME totals are reduce-scattered shards and are glued back into
    the full vector via :func:`reassemble_shared`; everything else is
    replicated across workers and worker 0 is taken.
    """

    def first(x):
        a = np.asarray(x)
        return a[0] if (a.ndim >= 1 and a.shape[0] == world) else a

    if strategy == FrameStrategy.SHARED_FRAME:
        data = jax.tree.map(
            lambda x: reassemble_shared(x, world, frame_shards),
            st.total.data)
    else:
        data = jax.tree.map(first, st.total.data)
    return AdaptiveResult(
        data=data, num=int(first(st.total.num)),
        stopped=bool(first(st.stop)), epochs=int(first(st.epoch)),
        stop_epoch=int(first(st.stop_epoch)),
        aux=jax.tree.map(first, st.aux), state=st)

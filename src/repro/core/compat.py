"""JAX version-compatibility resolvers.

The codebase targets the modern JAX surface (``jax.shard_map``,
``jax.lax.axis_size``, ``jax.sharding.AxisType``); older installs (≤ 0.4.x)
ship the same functionality under different names.  Everything that touches a
version-sensitive API goes through this module so the rest of the code reads
as if it were written against one JAX.

Resolved here:

* :func:`shard_map` — ``jax.shard_map`` (new) or
  ``jax.experimental.shard_map.shard_map`` (old); the new ``check_vma``
  kwarg maps onto the old ``check_rep``.
* :func:`axis_size` — ``jax.lax.axis_size`` or a ``psum(1)`` fallback
  (identical value inside vmap/shard_map; traced instead of static, which
  every call site tolerates).
* :func:`make_mesh` — forwards ``axis_types`` only where supported (older
  meshes are implicitly fully ``Auto``, so dropping the kwarg is lossless
  for our usage).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm, "check_vma"
    from jax.experimental.shard_map import shard_map as sm  # JAX ≤ 0.4.x
    return sm, "check_rep"


_SHARD_MAP, _CHECK_KWARG = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on any JAX version (``check_vma``≡old ``check_rep``)."""
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KWARG: check_vma})


def axis_size(axis_name: Any) -> jax.Array:
    """Size of a mapped axis; works on JAX without ``jax.lax.axis_size``."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(jnp.ones((), jnp.int32), axis_name)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on any JAX version (older
    releases return a one-element list of per-program dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` (new) → ``jax.sharding.use_mesh`` (transitional) →
    ``with mesh:`` (the Mesh context manager, JAX ≤ 0.4.x).
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def make_mesh(shape, axes, *, auto_axis_types: bool = True, devices=None):
    """``jax.make_mesh`` forwarding ``axis_types`` only when supported.

    ``devices`` — explicit device list (e.g. ``jax.devices()[:W]`` for a
    worker mesh smaller than the host's device count); every supported JAX
    accepts it, so it is forwarded unconditionally when given.
    """
    kwargs = {} if devices is None else {"devices": tuple(devices)}
    try:
        from jax.sharding import AxisType  # JAX ≥ 0.5
    except ImportError:
        return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
    if not auto_axis_types:
        return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(tuple(axes)),
                         **kwargs)

"""Execution substrates — *where* the epoch engine's W workers run.

The engine (:func:`repro.core.epoch.run_worker`) is written against the
:class:`~repro.core.frames.Collectives` abstraction, so the same per-worker
program admits three executions:

SEQUENTIAL   W = 1, identity collectives — the correctness oracle.
VMAP         W virtual workers on one device via ``vmap(axis_name=...)``;
             collectives are simulated (psum = sum over the mapped axis).
             This is how tests and the paper-figure benchmarks run on CPU.
SHARD_MAP    W real devices on a mesh axis via ``shard_map`` (through the
             :mod:`repro.core.compat` resolver); collectives lower to real
             all-reduce / reduce-scatter / all-gather, and the SHARED_FRAME
             F < W path uses the paper's grouped reduce-scatter +
             cross-group all-reduce (``axis_index_groups``) instead of the
             vmap psum+slice reference form.

The invariant the substrate-equivalence harness
(:func:`repro.core.conformance.run_substrate_equivalence`) enforces: for any
(instance, strategy, W, F) the three substrates produce **bit-identical**
``total.num`` and trimmed frame data.  Frames are integer pytrees, so real
collectives cannot diverge from the simulated semantics by reduction order.

On a single-device host, run tests with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before the first
jax import) to give SHARD_MAP real devices — exactly what the CI
``substrate-shardmap`` job does.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional

import jax

PyTree = Any

WORKER_AXIS = "workers"


class Substrate(enum.Enum):
    """How the engine's W workers are executed (see module docstring)."""

    SEQUENTIAL = "sequential"
    VMAP = "vmap"
    SHARD_MAP = "shard_map"


def resolve_substrate(substrate: "Substrate | str | None",
                      world: int = 1) -> Substrate:
    """Normalize a substrate spec; ``None`` → the historical default
    (sequential at W=1, vmap otherwise)."""
    if substrate is None:
        return Substrate.SEQUENTIAL if world == 1 else Substrate.VMAP
    return Substrate(substrate) if isinstance(substrate, str) else substrate


def unavailable_reason(substrate: "Substrate | str",
                       world: int) -> Optional[str]:
    """Why ``substrate`` cannot run ``world`` workers here (None = it can)."""
    sub = resolve_substrate(substrate, world)
    if sub == Substrate.SEQUENTIAL and world != 1:
        return f"sequential substrate is the W=1 oracle (got W={world})"
    if sub == Substrate.SHARD_MAP:
        have = len(jax.devices())
        if have < world:
            return (f"shard_map needs ≥{world} devices, have {have} — set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{world} before importing jax")
    return None


def available_substrates(world: int) -> tuple:
    """The substrates that can execute ``world`` workers on this host."""
    return tuple(s for s in Substrate
                 if unavailable_reason(s, world) is None)


def worker_mesh(world: int, axis: str = WORKER_AXIS, devices=None):
    """A 1-D mesh of ``world`` devices for the engine's worker axis.

    ``devices`` — an explicit device list (any subset of ``jax.devices()``,
    leading or not: the serving placement layer leases *disjoint* submeshes,
    so concurrent sessions must be buildable on e.g. devices ``[4..7]``).
    Default: the historical leading ``jax.devices()[:world]``.
    """
    from .compat import make_mesh
    if devices is None:
        reason = unavailable_reason(Substrate.SHARD_MAP, world)
        if reason is not None:
            raise RuntimeError(reason)
        devices = jax.devices()[:world]
    devices = list(devices)
    if len(devices) != world:
        raise ValueError(f"worker_mesh needs exactly world={world} devices, "
                         f"got {len(devices)}")
    return make_mesh((world,), (axis,), devices=devices)


def mesh_device_ids(mesh) -> tuple:
    """The flat device ids of a mesh, in mesh order — the part of a stepper
    cache key that distinguishes same-shape programs bound to different
    submeshes."""
    return tuple(d.id for d in mesh.devices.flat)


@dataclasses.dataclass(frozen=True)
class EpochStepper:
    """Single-epoch stepping of the engine on a substrate (serving path).

    ``init(seed)`` returns the primed epoch-0 state with every leaf stacked
    per worker (leading dim ``world``) — the same layout
    :func:`run_on_substrate` returns.  ``step(state, seed)`` advances exactly
    one epoch; the underlying program is jitted once per stepper and takes
    the seed as a traced scalar, so the serving scheduler can cache ONE
    stepper per session *shape* (instance config × strategy × W × F ×
    substrate × fold) and run any number of differently-seeded queries
    through it without recompiling.  ``active(state)`` is the host-side
    continuation predicate (all workers' verdicts are in lockstep).

    The invariant that makes checkpoint/resume and scheduling sound:
    ``step^n(init(seed))`` is bit-identical to the fused ``while_loop`` run
    of :func:`run_on_substrate` — the inter-epoch state is a value pytree,
    so where it is materialized (device loop, host loop, or a checkpoint on
    disk) cannot change the trajectory.
    """

    substrate: "Substrate"
    world: int
    cfg: Any
    fold: Optional[int]
    init_fn: Any = dataclasses.field(repr=False)
    step_fn: Any = dataclasses.field(repr=False)

    def init(self, seed: int):
        return self.init_fn(seed)

    def step(self, state, seed: int):
        import jax.numpy as jnp
        return self.step_fn(state, jnp.asarray(seed, jnp.uint32))

    def active(self, state) -> bool:
        import numpy as np
        stop = bool(np.asarray(state.stop).reshape(-1)[0])
        epoch = int(np.asarray(state.epoch).reshape(-1)[0])
        return (not stop) and epoch < self.cfg.max_epochs

    def run(self, seed: int):
        """Host-driven run to completion (the stepping-path oracle)."""
        st = self.init(seed)
        while self.active(st):
            st = self.step(st, seed)
        return st


def make_stepper(sample_fn, check_fn, template: PyTree, init_carry: PyTree,
                 world: int, cfg, *,
                 substrate: "Substrate | str | None" = None,
                 frame_shards: int = 0, fold: Optional[int] = None,
                 mesh=None, mesh_axis: Optional[str] = None) -> EpochStepper:
    """Build an :class:`EpochStepper` for one engine configuration.

    Key derivation matches the run-to-completion substrates exactly: the
    logical worker streams are ``jax.random.split(key(seed), world·k)``
    (k = fold or 1), reshaped ``(world, k)`` so physical worker p carries
    logical streams ``p·k … p·k+k−1`` — with ``fold=None`` this degenerates
    to the historical ``split(key(seed), world)`` per-worker streams.  With
    ``fold`` set, ``init_carry`` must already be stacked ``(k, ...)`` per
    logical stream (None is fine).
    """
    import jax.numpy as jnp

    from .epoch import AXIS, make_program
    from .frames import axis_collectives, sequential_collectives

    sub = resolve_substrate(
        substrate if substrate is not None
        else getattr(cfg, "substrate", None), world)
    reason = unavailable_reason(sub, world)
    if reason is not None:
        raise RuntimeError(f"substrate {sub.value!r}: {reason}")
    k = fold or 1

    def worker_keys(seed: int):
        keys = jax.random.split(jax.random.key(seed), world * k)
        return keys.reshape(world, k) if fold is not None \
            else keys.reshape(world)

    wids = jnp.arange(world, dtype=jnp.int32)

    if sub == Substrate.SEQUENTIAL:
        colls = sequential_collectives()
        axis = None
        mesh = None
    elif sub == Substrate.VMAP:
        colls = axis_collectives(AXIS, world, frame_shards=frame_shards)
        axis = AXIS
        mesh = None
    else:  # SHARD_MAP
        mesh = mesh if mesh is not None else worker_mesh(world)
        axis = mesh_axis if mesh_axis is not None else mesh.axis_names[0]
        if mesh.shape[axis] != world:
            raise ValueError(f"mesh axis {axis!r} has size "
                             f"{mesh.shape[axis]}, expected world={world}")
        colls = axis_collectives(axis, world, frame_shards=frame_shards,
                                 grouped=True)

    def make_prog(seed_arr):
        return make_program(sample_fn, check_fn, template, cfg, colls,
                            seed_scalar=seed_arr, fold=fold)

    if sub == Substrate.SEQUENTIAL:
        def init_raw(seed_arr, keys):
            st = make_prog(seed_arr).init(keys[0], jnp.int32(0), init_carry)
            return jax.tree.map(lambda x: jnp.asarray(x)[None], st)

        def step_raw(st, seed_arr):
            inner = jax.tree.map(lambda x: x[0], st)
            out = make_prog(seed_arr).body(inner, jnp.int32(0))
            return jax.tree.map(lambda x: jnp.asarray(x)[None], out)
    elif sub == Substrate.VMAP:
        def init_raw(seed_arr, keys):
            p = make_prog(seed_arr)
            return jax.vmap(lambda kk, w: p.init(kk, w, init_carry),
                            axis_name=axis)(keys, wids)

        def step_raw(st, seed_arr):
            return jax.vmap(make_prog(seed_arr).body, axis_name=axis)(st, wids)
    else:
        from jax.sharding import PartitionSpec as P

        from .compat import shard_map

        def _mapped(fn):
            return shard_map(fn, mesh=mesh, in_specs=(P(axis), P(axis)),
                             out_specs=P(axis), check_vma=False)

        def init_raw(seed_arr, keys):
            p = make_prog(seed_arr)

            def per_worker(kk, ws):
                st = p.init(kk[0], ws[0], init_carry)
                return jax.tree.map(lambda x: jnp.asarray(x)[None], st)

            return _mapped(per_worker)(keys, wids)

        def step_raw(st, seed_arr):
            p = make_prog(seed_arr)

            def per_worker(stw, ws):
                out = p.body(jax.tree.map(lambda x: x[0], stw), ws[0])
                return jax.tree.map(lambda x: jnp.asarray(x)[None], out)

            return _mapped(per_worker)(st, wids)

    step_jit = jax.jit(step_raw)
    init_jit = jax.jit(init_raw)

    def init_fn(seed: int):
        return init_jit(jnp.asarray(seed, jnp.uint32), worker_keys(seed))

    return EpochStepper(substrate=sub, world=world, cfg=cfg, fold=fold,
                        init_fn=init_fn, step_fn=step_jit)


def run_on_substrate(sample_fn, check_fn, template: PyTree,
                     init_carry: PyTree, seed: int, world: int, cfg,
                     *, substrate: "Substrate | str | None" = None,
                     frame_shards: int = 0, mesh=None,
                     mesh_axis: Optional[str] = None):
    """Run the epoch engine on the chosen substrate.

    Returns an :class:`~repro.core.epoch.EpochState` whose leaves are stacked
    per worker along a new leading axis of size ``world`` on **every**
    substrate (sequential results gain a leading axis of 1), so callers can
    treat the three substrates uniformly.

    ``substrate=None`` defers to ``cfg.substrate``, then to the historical
    default (sequential at W=1, vmap otherwise).  The per-worker RNG streams
    (``jax.random.split(key(seed), world)``) and the INDEXED_FRAME frame
    indices are substrate-independent by construction — that is what makes
    bit-identity across substrates possible at all.
    """
    from .epoch import run_sharded, run_virtual, run_worker
    from .frames import sequential_collectives

    import jax.numpy as jnp

    sub = resolve_substrate(
        substrate if substrate is not None
        else getattr(cfg, "substrate", None), world)
    reason = unavailable_reason(sub, world)
    if reason is not None:
        raise RuntimeError(f"substrate {sub.value!r}: {reason}")

    if sub == Substrate.VMAP:
        return run_virtual(sample_fn, check_fn, template, init_carry, seed,
                           world, cfg, frame_shards=frame_shards)
    if sub == Substrate.SHARD_MAP:
        mesh = mesh if mesh is not None else worker_mesh(world)
        axis = mesh_axis if mesh_axis is not None else mesh.axis_names[0]
        if mesh.shape[axis] != world:
            raise ValueError(
                f"mesh axis {axis!r} has size {mesh.shape[axis]}, "
                f"expected world={world}")
        return run_sharded(sample_fn, check_fn, template, init_carry, seed,
                           mesh, axis, cfg, frame_shards=frame_shards)
    # SEQUENTIAL: same key derivation as the mapped substrates (split once,
    # take worker 0) so W=1 results are bit-identical across substrates.
    key = jax.random.split(jax.random.key(seed), 1)[0]
    st = run_worker(sample_fn, check_fn, template, init_carry, key, cfg,
                    colls=sequential_collectives(),
                    seed_scalar=jnp.asarray(seed, jnp.uint32),
                    worker_id=jnp.int32(0))
    return jax.tree.map(lambda x: jnp.asarray(x)[None], st)

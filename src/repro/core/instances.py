"""ADS instance layer — workloads as first-class, registered objects.

The paper's framework (Algorithm 1/2) is generic over *any* adaptive
sampling algorithm; the epoch engine in :mod:`repro.core.epoch` already is.
This module makes that genericity concrete: an :class:`AdaptiveInstance`
bundles everything the engine plus the test/benchmark harnesses need about
one workload —

    SAMPLE()        sample_fn   (key, carry) -> (StateFrame delta, carry)
    CHECKFORSTOP()  check_fn    (StateFrame total) -> (stop, aux)
    frame shape     template    (padded for SHARED_FRAME sharding)
    ground truth    oracle      exact reference value of the estimand
    extraction      estimate    reduced frame data -> estimate vector

and a **registry** maps workload names to instances, so strategy sweeps,
the conformance harness (:mod:`repro.core.conformance`) and benchmarks can
iterate ``for name in available_instances()`` instead of hard-coding
KADABRA.

Registered out of the box:

* ``kadabra``       — betweenness centrality (the paper's case study)
* ``triangles``     — triangle counting via wedge sampling
* ``reachability``  — s–t reachability under edge percolation
* ``wrs``           — weighted-mean estimation via alias-table draws
                      (Hübschle-Schneider & Sanders weighted sampling)
* ``diameter``      — graph-diameter estimation via double-sweep BFS
* ``gradvar``       — adaptive gradient-variance accumulation (mean
                      per-example gradient norm to a relative-SEM target)

Adding a workload = implement ``build()`` returning a
:class:`BuiltInstance` + ``register_instance(...)`` (see README §Instance
layer).  Graph modules are imported lazily inside ``build`` so importing
this module stays cheap and cycle-free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Protocol, Tuple, runtime_checkable

import jax
import numpy as np

from .adaptive import AdaptiveResult, run_adaptive
from .frames import FrameStrategy, shard_frame_pad

PyTree = Any


@dataclasses.dataclass(frozen=True)
class BuiltInstance:
    """One workload, fully materialized for a given (world, strategy).

    ``true_len`` is the unpadded leading length of vector frame leaves;
    :meth:`trim` strips SHARED_FRAME padding so estimates and cross-strategy
    comparisons always happen on canonical (unpadded) data.
    """

    name: str
    sample_fn: Callable
    check_fn: Callable
    template: PyTree
    init_carry: PyTree
    samples_per_round: int        # frame.num contribution of one sample_fn call
    true_len: int
    eps: float                    # tolerance in estimate units
    delta: float
    oracle: np.ndarray            # exact value of the estimand (flat vector)
    estimate: Callable[[PyTree, float], np.ndarray]  # (trimmed data, τ) -> vec
    rounds_per_epoch: int = 2
    max_epochs: int = 4000

    def trim(self, data: PyTree) -> PyTree:
        def t(x):
            a = np.asarray(x)
            return a[: self.true_len] if a.ndim >= 1 else a
        return jax.tree.map(t, data)


@runtime_checkable
class AdaptiveInstance(Protocol):
    """A registrable ADS workload: a name plus a ``build`` factory."""

    name: str

    def build(self, *, world: int = 1,
              strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
              ) -> BuiltInstance: ...


_REGISTRY: Dict[str, AdaptiveInstance] = {}


def register_instance(instance: AdaptiveInstance, *,
                      overwrite: bool = False) -> AdaptiveInstance:
    if not overwrite and instance.name in _REGISTRY:
        raise ValueError(f"instance {instance.name!r} already registered")
    _REGISTRY[instance.name] = instance
    return instance


def get_instance(name: str) -> AdaptiveInstance:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown instance {name!r}; "
                       f"available: {available_instances()}") from None


def available_instances() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def run_instance(instance: "str | AdaptiveInstance", *,
                 strategy: "str | FrameStrategy" = FrameStrategy.LOCAL_FRAME,
                 world: int = 1, seed: int = 0,
                 substrate: "str | None" = None, frame_shards: int = 0,
                 ) -> Tuple[np.ndarray, AdaptiveResult, BuiltInstance]:
    """Build + run one registered workload; returns (estimate, result, built).

    ``substrate`` selects the execution substrate (core/substrate.py:
    ``"sequential"`` | ``"vmap"`` | ``"shard_map"``; None → sequential at
    W=1, vmap otherwise).  ``frame_shards`` is the paper's F for
    SHARED_FRAME (0 → F=W); frames are padded to W, which every F | W
    divides, so any registered instance runs at any valid (W, F).
    """
    inst = get_instance(instance) if isinstance(instance, str) else instance
    strat = FrameStrategy(strategy) if isinstance(strategy, str) else strategy
    built = inst.build(world=world, strategy=strat)
    res = run_adaptive(built.sample_fn, built.check_fn, built.template,
                       strategy=strat, world=world, seed=seed,
                       rounds_per_epoch=built.rounds_per_epoch,
                       max_epochs=built.max_epochs,
                       init_carry=built.init_carry,
                       substrate=substrate, frame_shards=frame_shards)
    est = built.estimate(built.trim(res.data), float(res.num))
    return est, res, built


# ---------------------------------------------------------------------------
# Built-in instances.  Graph construction / preprocessing / exact oracles are
# memoized per instance (they are pure functions of the frozen params).
# ---------------------------------------------------------------------------

_CACHE: Dict[Any, Any] = {}


def _cached(key, fn):
    if key not in _CACHE:
        _CACHE[key] = fn()
    return _CACHE[key]


def _pad_for(n: int, world: int, strategy: FrameStrategy) -> int:
    return shard_frame_pad(n, world) if strategy == FrameStrategy.SHARED_FRAME \
        else n


@dataclasses.dataclass(frozen=True)
class KadabraInstance:
    """Betweenness-centrality approximation (the paper's case study)."""

    name: str = "kadabra"
    n_vertices: int = 32
    n_edges: int = 96
    graph_seed: int = 1
    eps: float = 0.1
    delta: float = 0.1
    batch: int = 32
    rounds_per_epoch: int = 2
    max_epochs: int = 4000
    # Exact oracles are for conformance-sized graphs; benchmark presets
    # disable them (oracle = NaN; don't run conformance on those).
    compute_oracle: bool = True

    def _graph(self):
        def make():
            from ..graphs import brandes_exact, erdos_renyi
            from ..graphs.kadabra import preprocess
            g = erdos_renyi(self.n_vertices, self.n_edges, seed=self.graph_seed)
            pre = preprocess(g, self.eps, self.delta)
            oracle = brandes_exact(g) if self.compute_oracle \
                else np.full((g.n,), np.nan)
            return g, pre, oracle
        return _cached(("kadabra", self), make)

    def build(self, *, world: int = 1,
              strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
              ) -> BuiltInstance:
        from ..core.stopping import KadabraCondition
        from ..graphs.kadabra import frame_template, make_sample_fn
        g, pre, oracle = self._graph()
        pad = _pad_for(g.n, world, strategy)
        sample_fn = make_sample_fn(g, pre, self.batch, pad_to=pad)
        cond = KadabraCondition(eps=self.eps, delta=self.delta,
                                omega=pre.omega, n_vertices=g.n)

        def estimate(data: PyTree, num: float) -> np.ndarray:
            return np.asarray(data, np.float64) / max(num, 1.0)

        return BuiltInstance(
            name=self.name, sample_fn=sample_fn, check_fn=cond,
            template=frame_template(g, pad), init_carry=None,
            samples_per_round=self.batch, true_len=g.n,
            eps=self.eps, delta=self.delta, oracle=oracle,
            estimate=estimate, rounds_per_epoch=self.rounds_per_epoch,
            max_epochs=self.max_epochs)


@dataclasses.dataclass(frozen=True)
class TrianglesInstance:
    """Triangle counting via wedge sampling (estimate in count units)."""

    name: str = "triangles"
    n_vertices: int = 40
    m_per: int = 3
    graph_seed: int = 2
    eps_p: float = 0.05           # Hoeffding tolerance on the closure prob
    delta: float = 0.1
    batch: int = 64
    rounds_per_epoch: int = 2
    max_epochs: int = 4000
    # triangles_exact is dense O(n³) — benchmark presets disable it.
    compute_oracle: bool = True

    def _graph(self):
        def make():
            from ..graphs import barabasi_albert
            from ..graphs.triangles import triangles_exact, wedge_weights
            g = barabasi_albert(self.n_vertices, self.m_per,
                                seed=self.graph_seed)
            _, w_total = wedge_weights(g)
            t_exact = triangles_exact(g) if self.compute_oracle \
                else float("nan")
            return g, w_total, t_exact
        return _cached(("triangles", self), make)

    def build(self, *, world: int = 1,
              strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
              ) -> BuiltInstance:
        import jax.numpy as jnp

        from ..core.stopping import WedgeClosureCondition
        from ..graphs.triangles import make_wedge_sample_fn, triangle_estimate
        g, w_total, t_exact = self._graph()
        pad = _pad_for(g.n, world, strategy)
        sample_fn = make_wedge_sample_fn(g, self.batch, pad_to=pad)
        cond = WedgeClosureCondition(eps=self.eps_p, delta=self.delta,
                                     total_wedges=w_total)
        eps_count = self.eps_p * w_total / 3.0

        def estimate(data: PyTree, num: float) -> np.ndarray:
            return np.asarray([triangle_estimate(data, num, w_total)])

        return BuiltInstance(
            name=self.name, sample_fn=sample_fn, check_fn=cond,
            template=jnp.zeros((pad,), jnp.int32), init_carry=None,
            samples_per_round=self.batch, true_len=g.n,
            eps=eps_count, delta=self.delta,
            oracle=np.asarray([t_exact]), estimate=estimate,
            rounds_per_epoch=self.rounds_per_epoch,
            max_epochs=self.max_epochs)


@dataclasses.dataclass(frozen=True)
class ReachabilityInstance:
    """s–t reachability probability under edge percolation (tiny graph so
    the exact-enumeration oracle stays feasible)."""

    name: str = "reachability"
    rows: int = 3
    cols: int = 3
    s: int = 0
    t: int = 8
    pi: float = 0.7               # per-edge survival probability
    eps: float = 0.05
    delta: float = 0.1
    batch: int = 64
    rounds_per_epoch: int = 2
    max_epochs: int = 4000
    # Exact enumeration is 2^m — infeasible beyond ~20 edges.  Benchmark
    # presets disable it (oracle = NaN; don't run conformance on those).
    compute_oracle: bool = True

    def _graph(self):
        def make():
            from ..graphs import grid2d
            from ..graphs.reachability import reachability_exact
            g = grid2d(self.rows, self.cols)
            p_exact = reachability_exact(g, self.s, self.t, self.pi) \
                if self.compute_oracle else float("nan")
            return g, p_exact
        return _cached(("reachability", self), make)

    def build(self, *, world: int = 1,
              strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
              ) -> BuiltInstance:
        from ..core.stopping import PercolationCondition, hoeffding_tau_needed
        from ..graphs.reachability import (frame_template,
                                           make_percolation_sample_fn)
        g, p_exact = self._graph()
        pad = _pad_for(g.n, world, strategy)
        sample_fn = make_percolation_sample_fn(g, self.s, self.t, self.pi,
                                               self.batch, pad_to=pad)
        # ω analog: the static Hoeffding bound caps the sample count
        omega = int(np.ceil(float(hoeffding_tau_needed(self.eps,
                                                       self.delta))))
        cond = PercolationCondition(eps=self.eps, delta=self.delta,
                                    max_samples=omega)

        def estimate(data: PyTree, num: float) -> np.ndarray:
            return np.asarray([float(data["s1"]) / max(num, 1.0)])

        return BuiltInstance(
            name=self.name, sample_fn=sample_fn, check_fn=cond,
            template=frame_template(g, pad), init_carry=None,
            samples_per_round=self.batch, true_len=g.n,
            eps=self.eps, delta=self.delta,
            oracle=np.asarray([p_exact]), estimate=estimate,
            rounds_per_epoch=self.rounds_per_epoch,
            max_epochs=self.max_epochs)


@dataclasses.dataclass(frozen=True)
class WeightedSamplingInstance:
    """Weighted-mean estimation over alias-table draws (parallel weighted
    random sampling, Hübschle-Schneider & Sanders).

    Heavy-tailed (Pareto) weights — the regime alias tables exist for —
    over quantized values bounded away from 0 so the relative-error
    stopping target is well-conditioned.  The exact oracle is O(n) and is
    always computed.
    """

    name: str = "wrs"
    n_items: int = 256
    weight_seed: int = 3
    rtol: float = 0.05            # relative half-width target on μ̂
    delta: float = 0.1
    batch: int = 128
    rounds_per_epoch: int = 2
    max_epochs: int = 4000
    # int32 moment sums stay exact while max_samples·(value_scale−1)² < 2³¹.
    max_samples: int = 1 << 19
    value_scale: int = 32

    def _setup(self):
        def make():
            from ..sampling.alias import build_alias_table, weighted_mean_exact
            rng = np.random.default_rng(self.weight_seed)
            w = rng.pareto(1.5, size=self.n_items) + 1e-3
            values_q = rng.integers(self.value_scale // 4, self.value_scale,
                                    size=self.n_items)
            table = build_alias_table(w)
            mu = weighted_mean_exact(w, values_q, self.value_scale)
            return table, values_q, mu
        return _cached(("wrs", self), make)

    def build(self, *, world: int = 1,
              strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
              ) -> BuiltInstance:
        import jax.numpy as jnp

        from ..core.stopping import RelativeErrorCondition
        from ..sampling.alias import (make_weighted_sample_fn,
                                      weighted_frame_template)
        table, values_q, mu = self._setup()
        pad = _pad_for(self.n_items, world, strategy)
        sample_fn = make_weighted_sample_fn(table,
                                            jnp.asarray(values_q, jnp.int32),
                                            self.batch, pad_to=pad)
        cond = RelativeErrorCondition(rtol=self.rtol, delta=self.delta,
                                      scale=float(self.value_scale),
                                      max_samples=self.max_samples)
        scale = float(self.value_scale)

        def estimate(data: PyTree, num: float) -> np.ndarray:
            return np.asarray([float(data["s1"]) / (scale * max(num, 1.0))])

        return BuiltInstance(
            name=self.name, sample_fn=sample_fn, check_fn=cond,
            template=weighted_frame_template(self.n_items, pad),
            init_carry=None, samples_per_round=self.batch,
            true_len=self.n_items,
            eps=2.0 * self.rtol * mu, delta=self.delta,
            oracle=np.asarray([mu]), estimate=estimate,
            rounds_per_epoch=self.rounds_per_epoch,
            max_epochs=self.max_epochs)


@dataclasses.dataclass(frozen=True)
class DiameterInstance:
    """Graph-diameter estimation via double-sweep BFS lower bounds.

    ``kind="grid"`` (road-network analog: high diameter, the double sweep's
    best case) or ``kind="er"``.  Assumes one connected component (the gap
    certificate reasons about the global diameter); the conformance-sized
    grid satisfies this by construction.  ``diameter_exact`` is O(n·m) —
    benchmark presets disable it.
    """

    name: str = "diameter"
    kind: str = "grid"
    rows: int = 5
    cols: int = 5
    n_vertices: int = 64          # for kind="er"
    n_edges: int = 192
    graph_seed: int = 4
    gap: int = 0                  # certified |diam − estimate| tolerance
    batch: int = 8
    rounds_per_epoch: int = 2
    max_epochs: int = 4000
    max_samples: int = 4096
    compute_oracle: bool = True

    def _graph(self):
        def make():
            from ..graphs import erdos_renyi, grid2d
            from ..graphs.diameter import diameter_exact
            g = grid2d(self.rows, self.cols) if self.kind == "grid" \
                else erdos_renyi(self.n_vertices, self.n_edges,
                                 seed=self.graph_seed)
            diam = float(diameter_exact(g)) if self.compute_oracle \
                else float("nan")
            return g, diam
        return _cached(("diameter", self), make)

    def build(self, *, world: int = 1,
              strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
              ) -> BuiltInstance:
        from ..core.stopping import EccentricityGapCondition
        from ..graphs.diameter import (diameter_estimate, frame_template,
                                       make_sweep_sample_fn)
        g, diam = self._graph()
        bins = g.n + 1
        pad = _pad_for(bins, world, strategy)
        sample_fn = make_sweep_sample_fn(g, self.batch, gap=self.gap,
                                         pad_to=pad)
        cond = EccentricityGapCondition(gap=self.gap,
                                        max_samples=self.max_samples)

        def estimate(data: PyTree, num: float) -> np.ndarray:
            return np.asarray([diameter_estimate(data["ecc_hist"])])

        return BuiltInstance(
            name=self.name, sample_fn=sample_fn, check_fn=cond,
            template=frame_template(g, pad), init_carry=None,
            samples_per_round=self.batch, true_len=bins,
            eps=self.gap + 0.5, delta=0.0,
            oracle=np.asarray([diam]), estimate=estimate,
            rounds_per_epoch=self.rounds_per_epoch,
            max_epochs=self.max_epochs)


@dataclasses.dataclass(frozen=True)
class GradVarianceInstance:
    """Adaptive gradient-variance accumulation as a serving-capable ADS
    workload: estimate the mean per-example gradient norm of a fixed
    linear-regression iterate, stopping once the relative standard error is
    below ``rtol`` (:class:`~repro.core.stopping.GradVarianceCondition` —
    the same condition the training-side device loop in
    ``optim/adaptive.py`` uses).  Norms are integer-quantized (the wrs
    trick) so frames reduce exactly under every strategy; the oracle is the
    O(n) population mean, always computed.
    """

    name: str = "gradvar"
    n_examples: int = 256
    dim: int = 8
    data_seed: int = 5
    rtol: float = 0.05
    batch: int = 64
    rounds_per_epoch: int = 2
    max_epochs: int = 4000
    # int32 moment sums stay exact while max_samples·(value_scale−1)² < 2³¹.
    max_samples: int = 1 << 19
    value_scale: int = 32

    def _setup(self):
        def make():
            from ..optim.adaptive import quantized_grad_norms
            return quantized_grad_norms(self.n_examples, self.dim,
                                        self.data_seed, self.value_scale)
        return _cached(("gradvar", self), make)

    def build(self, *, world: int = 1,
              strategy: FrameStrategy = FrameStrategy.LOCAL_FRAME
              ) -> BuiltInstance:
        from ..core.stopping import GradVarianceCondition
        from ..optim.adaptive import (gradnorm_frame_template,
                                      make_gradnorm_sample_fn)
        gq, mu = self._setup()
        pad = _pad_for(self.n_examples, world, strategy)
        sample_fn = make_gradnorm_sample_fn(gq, self.batch, pad_to=pad)
        cond = GradVarianceCondition(rtol=self.rtol,
                                     max_samples=self.max_samples)
        scale = float(self.value_scale)

        def estimate(data: PyTree, num: float) -> np.ndarray:
            return np.asarray([float(data["s1"]) / (scale * max(num, 1.0))])

        # rel-SEM stopping is a standard-error target, not a (ε,δ) bound:
        # the estimate sits within a few SEMs of the mean, so ε = 4·rtol·μ
        # is the conformance-harness tolerance (validated over seeds 0–2).
        return BuiltInstance(
            name=self.name, sample_fn=sample_fn, check_fn=cond,
            template=gradnorm_frame_template(self.n_examples, pad),
            init_carry=None, samples_per_round=self.batch,
            true_len=self.n_examples,
            eps=4.0 * self.rtol * mu, delta=0.0,
            oracle=np.asarray([mu]), estimate=estimate,
            rounds_per_epoch=self.rounds_per_epoch,
            max_epochs=self.max_epochs)


register_instance(KadabraInstance())
register_instance(TrianglesInstance())
register_instance(ReachabilityInstance())
register_instance(WeightedSamplingInstance())
register_instance(DiameterInstance())
register_instance(GradVarianceInstance())

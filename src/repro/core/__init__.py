"""The paper's core system: state frames, the epoch engine, stopping rules,
the multi-workload ADS instance layer, and the cross-strategy conformance
harness."""

from .adaptive import AdaptiveResult, run_adaptive
from .frames import (Collectives, FrameStrategy, StateFrame, accumulate,
                     axis_collectives, combine, sequential_collectives,
                     shard_frame_pad, zeros_like_frame)
from .instances import (AdaptiveInstance, BuiltInstance, available_instances,
                        get_instance, register_instance, run_instance)

__all__ = [
    "AdaptiveInstance", "AdaptiveResult", "BuiltInstance", "Collectives",
    "FrameStrategy", "StateFrame", "accumulate", "available_instances",
    "axis_collectives", "combine", "get_instance", "register_instance",
    "run_adaptive", "run_instance", "sequential_collectives",
    "shard_frame_pad", "zeros_like_frame",
]

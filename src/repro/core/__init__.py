"""The paper's core system: state frames, the epoch engine, stopping rules,
the multi-workload ADS instance layer, the execution-substrate abstraction
(sequential / vmap / shard_map), and the conformance + substrate-equivalence
harnesses."""

from .adaptive import AdaptiveResult, result_from_state, run_adaptive
from .epoch import EpochConfig, EpochProgram, EpochState, make_program
from .frames import (Collectives, FrameStrategy, StateFrame, accumulate,
                     axis_collectives, combine, sequential_collectives,
                     shard_frame_pad, shard_groups, zeros_like_frame)
from .instances import (AdaptiveInstance, BuiltInstance, available_instances,
                        get_instance, register_instance, run_instance)
from .substrate import (EpochStepper, Substrate, available_substrates,
                        make_stepper, resolve_substrate, run_on_substrate,
                        worker_mesh)

__all__ = [
    "AdaptiveInstance", "AdaptiveResult", "BuiltInstance", "Collectives",
    "EpochConfig", "EpochProgram", "EpochState", "EpochStepper",
    "FrameStrategy", "StateFrame", "Substrate", "accumulate",
    "available_instances", "available_substrates", "axis_collectives",
    "combine", "get_instance", "make_program", "make_stepper",
    "register_instance", "resolve_substrate", "result_from_state",
    "run_adaptive", "run_instance", "run_on_substrate",
    "sequential_collectives", "shard_frame_pad", "shard_groups",
    "worker_mesh", "zeros_like_frame",
]

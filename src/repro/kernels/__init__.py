"""Pallas TPU kernels for the compute hot spots (DESIGN.md §5).

| kernel            | hot spot                                                |
|-------------------|---------------------------------------------------------|
| ``frame_accum``   | Θ(T·n) state-frame accumulation (Alg. 2 line 27)        |
| ``bfs_frontier``  | one BFS level of SAMPLE() (CSR frontier expansion)      |
| ``alias_draw``    | batched alias-table draws (weighted sampling SAMPLE())  |
| ``flash_attention``| prefill/train attention with causal/window block skip  |
| ``ssm_scan``      | Mamba selective-scan recurrence                         |
| ``rglru_scan``    | RG-LRU gated linear recurrence                          |

``ops.py`` exposes jit'd wrappers (with ``interpret=`` switch: CPU validation
runs the kernel body in python); ``ref.py`` holds the pure-jnp oracles every
kernel is tested against across shape/dtype sweeps.
"""
from . import ops, ref  # noqa: F401

__all__ = ["ops", "ref"]

"""Pallas kernel: flash attention (causal GQA, optional sliding window).

Tiling: grid = (B, H, n_q_blocks, n_kv_blocks) with the KV axis innermost; a
VMEM scratch carries the streaming-softmax state (m, l, acc) across KV steps
for one Q block.  GQA is expressed in the *index map*: query head ``h``
reads KV head ``h // G`` — no KV duplication in HBM.  Causal/window block
skipping is a ``pl.when`` guard (a production TPU kernel would shrink the
grid instead; the guard keeps the block-skip semantics identical to the
unrolled XLA oracle while staying shape-generic).

MXU alignment: block sizes default to 128 multiples; ``hd`` is the matmul
minor dim (64/120/128/256 across the assigned archs — 120 pads to 128 lanes
on real hardware).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, window: int, n_kv_blocks: int,
            scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_k
    # block-level skip: strictly-future blocks, or fully-outside-window blocks
    live = k_start <= q_start + block_q - 1
    if window > 0:
        live = jnp.logical_and(live, k_start + block_k > q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos <= q_pos
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,S,hd); k,v: (B,KV,S,hd); GQA via index map. Causal."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, window=window,
        n_kv_blocks=nk, scale=1.0 / math.sqrt(hd))
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)

"""Pallas kernel: Mamba selective-scan recurrence  h_t = a_t⊙h_{t−1} + b_t.

Tiling: grid = (B, d_inner / BLOCK_D); each grid step keeps a
(S, BLOCK_D, N) slab of a/b in VMEM and walks the sequence with an in-kernel
``fori_loop`` (the recurrence is sequential in S but embarrassingly parallel
in (B, d_inner, N) — the VPU processes BLOCK_D·N lanes per step).  The
production variant for very long S processes S in chunks carrying h between
chunk launches (the chunk boundary state is exactly the decode state).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, h_ref, *, seq_len: int):
    # refs: (1, S, BLOCK_D, N); out h_ref same
    def step(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]
        h_ref[0, t] = h
        return h

    h0 = jnp.zeros_like(a_ref[0, 0])
    jax.lax.fori_loop(0, seq_len, step, h0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ssm_scan(a: jax.Array, b: jax.Array, *, block_d: int = 256,
             interpret: bool = False) -> jax.Array:
    """a, b: (B, S, D, N) f32 → all h_t (B, S, D, N)."""
    B, S, D, N = a.shape
    block_d = min(block_d, D)
    assert D % block_d == 0
    return pl.pallas_call(
        functools.partial(_kernel, seq_len=S),
        grid=(B, D // block_d),
        in_specs=[
            pl.BlockSpec((1, S, block_d, N), lambda b_, d: (b_, 0, d, 0)),
            pl.BlockSpec((1, S, block_d, N), lambda b_, d: (b_, 0, d, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, block_d, N),
                               lambda b_, d: (b_, 0, d, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D, N), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))

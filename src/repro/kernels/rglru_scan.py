"""Pallas kernel: RG-LRU gated linear recurrence  h_t = a_t⊙h_{t−1} + b_t
over (B, S, W) — the N=1 sibling of ``ssm_scan`` with wider channel tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, h_ref, *, seq_len: int):
    def step(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]
        h_ref[0, t] = h
        return h

    jax.lax.fori_loop(0, seq_len, step, jnp.zeros_like(a_ref[0, 0]))


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, block_w: int = 512,
               interpret: bool = False) -> jax.Array:
    """a, b: (B, S, W) f32 → all h_t (B, S, W)."""
    B, S, W = a.shape
    block_w = min(block_w, W)
    assert W % block_w == 0
    return pl.pallas_call(
        functools.partial(_kernel, seq_len=S),
        grid=(B, W // block_w),
        in_specs=[
            pl.BlockSpec((1, S, block_w), lambda b_, w: (b_, 0, w)),
            pl.BlockSpec((1, S, block_w), lambda b_, w: (b_, 0, w)),
        ],
        out_specs=pl.BlockSpec((1, S, block_w), lambda b_, w: (b_, 0, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))

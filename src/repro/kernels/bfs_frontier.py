"""Pallas kernel: one BFS level of KADABRA's SAMPLE() — CSR frontier
expansion with shortest-path counting.

For every arc (u→v):  agg[v] += σ[u] · [dist[u] == level].

Tiling: grid over edge blocks (the σ/dist vectors and the agg accumulator
stay VMEM-resident across the serial grid — sound on TPU where grid steps of
one core execute in order).  The gather σ[src] / scatter-add agg[dst] are
VPU-served from VMEM; edge blocks stream in via contiguous DMA.  This bounds
the kernel to graphs whose per-vertex state fits VMEM (~2M vertices at f32);
larger graphs run the vertex-blocked XLA path (``graphs/bfs.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(src_ref, dst_ref, sigma_ref, dist_ref, level_ref, agg_ref, *,
            n_blocks: int):
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        agg_ref[...] = jnp.zeros_like(agg_ref)

    src = src_ref[...]
    dst = dst_ref[...]
    level = level_ref[0]
    contrib = jnp.where(dist_ref[src] == level, sigma_ref[src], 0.0)
    # serial-grid scatter-add into the VMEM-resident accumulator
    agg_ref[...] = agg_ref[...] + jnp.zeros_like(agg_ref).at[dst].add(contrib)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def bfs_frontier(src: jax.Array, dst: jax.Array, sigma: jax.Array,
                 dist: jax.Array, level: jax.Array, *, block_e: int = 4096,
                 interpret: bool = False) -> jax.Array:
    """One frontier-expansion level.

    src/dst: (m,) int32 arcs; sigma: (n,) f32; dist: (n,) int32;
    level: scalar int32 → agg (n,) f32 (Σ of frontier σ into each vertex).
    Arcs padded with src=dst=n−1? No: pad arcs must point at a dead slot —
    callers pad with an extra sentinel vertex (sigma row n is appended here).
    """
    m = src.shape[0]
    n = sigma.shape[0]
    block_e = min(block_e, m)
    pad = (-m) % block_e
    if pad:  # sentinel self-loops on an appended dead vertex
        src = jnp.pad(src, (0, pad), constant_values=n)
        dst = jnp.pad(dst, (0, pad), constant_values=n)
    sigma_x = jnp.pad(sigma.astype(jnp.float32), (0, 1))
    dist_x = jnp.pad(dist, (0, 1), constant_values=jnp.iinfo(jnp.int32).max)
    mp = m + pad
    agg = pl.pallas_call(
        functools.partial(_kernel, n_blocks=mp // block_e),
        grid=(mp // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((n + 1,), lambda e: (0,)),
            pl.BlockSpec((n + 1,), lambda e: (0,)),
            pl.BlockSpec((1,), lambda e: (0,)),
        ],
        out_specs=pl.BlockSpec((n + 1,), lambda e: (0,)),
        out_shape=jax.ShapeDtypeStruct((n + 1,), jnp.float32),
        interpret=interpret,
    )(src, dst, sigma_x, dist_x, level[None])
    return agg[:n]

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def frame_accum_ref(frames: jax.Array) -> jax.Array:
    """(W, n) → (n,)."""
    if jnp.issubdtype(frames.dtype, jnp.floating):
        return jnp.sum(frames.astype(jnp.float32), axis=0).astype(frames.dtype)
    return jnp.sum(frames.astype(jnp.int32), axis=0).astype(frames.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int = 0) -> jax.Array:
    """q: (B,H,S,hd); k,v: (B,KV,S,hd) — causal GQA, materialized softmax."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)


def ssm_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(B,S,D,N) linear recurrence via associative scan (matches
    models/ssm.linear_scan)."""
    from repro.models.ssm import linear_scan
    return linear_scan(a.astype(jnp.float32), b.astype(jnp.float32), axis=1)


def rglru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    from repro.models.ssm import linear_scan
    return linear_scan(a.astype(jnp.float32), b.astype(jnp.float32), axis=1)


def bfs_frontier_ref(src: jax.Array, dst: jax.Array, sigma: jax.Array,
                     dist: jax.Array, level: jax.Array) -> jax.Array:
    """Matches graphs/bfs.py's frontier expansion (segment-sum form)."""
    contrib = jnp.where(dist[src] == level, sigma.astype(jnp.float32)[src],
                        0.0)
    return jax.ops.segment_sum(contrib, dst, num_segments=sigma.shape[0])


def alias_draw_ref(prob: jax.Array, alias: jax.Array, u1: jax.Array,
                   u2: jax.Array) -> jax.Array:
    """Batched alias-table draw: keep bucket ⌊u₁·n⌋ w.p. prob, else alias."""
    n = prob.shape[0]
    bucket = jnp.minimum((u1 * n).astype(jnp.int32), n - 1)
    return jnp.where(u2 < prob[bucket], bucket, alias[bucket])

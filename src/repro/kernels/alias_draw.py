"""Pallas kernel: batched alias-table draws — the O(1) weighted-sampling
hot loop (Hübschle-Schneider & Sanders).

For every draw b:  bucket = ⌊u₁·n⌋;  idx = bucket if u₂ < prob[bucket]
else alias[bucket].

Tiling: grid over draw blocks; the ``prob``/``alias`` tables stay
VMEM-resident across the serial grid while the uniform streams and the
index output are blocked — the same table-resident/stream-blocked shape as
``bfs_frontier``.  The two gathers per draw are VPU-served from VMEM, so
the kernel is bandwidth-bound on the u₁/u₂ streams.  Table size is bounded
by VMEM (~2M buckets at f32+i32); larger tables would need a two-level
(grouped) alias structure — out of scope here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(prob_ref, alias_ref, u1_ref, u2_ref, idx_ref, *, n: int):
    u1 = u1_ref[...]
    bucket = jnp.minimum((u1 * n).astype(jnp.int32), n - 1)
    keep = u2_ref[...] < prob_ref[bucket]
    idx_ref[...] = jnp.where(keep, bucket, alias_ref[bucket])


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def alias_draw(prob: jax.Array, alias: jax.Array, u1: jax.Array,
               u2: jax.Array, *, block_b: int = 4096,
               interpret: bool = False) -> jax.Array:
    """Batched alias draws.

    prob: (n,) f32 in [0,1]; alias: (n,) int32; u1/u2: (b,) f32 uniforms
    → idx (b,) int32 with P[idx = i] = wᵢ/Σw (exact for the table).
    """
    b = u1.shape[0]
    n = prob.shape[0]
    block_b = min(block_b, b)
    pad = (-b) % block_b
    if pad:  # padded draws hit bucket 0 and are sliced off below
        u1 = jnp.pad(u1, (0, pad))
        u2 = jnp.pad(u2, (0, pad), constant_values=1.0)
    bp = b + pad
    idx = pl.pallas_call(
        functools.partial(_kernel, n=n),
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((n,), lambda e: (0,)),
            pl.BlockSpec((n,), lambda e: (0,)),
            pl.BlockSpec((block_b,), lambda e: (e,)),
            pl.BlockSpec((block_b,), lambda e: (e,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda e: (e,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.int32),
        interpret=interpret,
    )(prob, alias, u1, u2)
    return idx[:b]

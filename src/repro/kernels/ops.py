"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the wrappers default to ``interpret=True`` — the
kernel body executes in python for correctness validation.  On a TPU backend
they run compiled.  ``use_kernels(False)`` (or backend ≠ tpu) falls back to
the pure-jnp oracles so the model code can call one entry point everywhere.
"""

from __future__ import annotations

import jax

from . import ref as _ref
from .alias_draw import alias_draw as _alias_kernel
from .bfs_frontier import bfs_frontier as _bfs_kernel
from .flash_attention import flash_attention as _fa_kernel
from .frame_accum import frame_accum as _fa_accum_kernel
from .rglru_scan import rglru_scan as _rg_kernel
from .ssm_scan import ssm_scan as _ssm_kernel

_FORCE: bool | None = None


def use_kernels(enable: bool | None) -> None:
    """Force kernels on/off (None → auto: on for TPU backends)."""
    global _FORCE
    _FORCE = enable


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_mode() -> str:
    """'compiled' | 'interpret' | 'ref'."""
    if _FORCE is False:
        return "ref"
    if _on_tpu():
        return "compiled"
    if _FORCE:
        return "interpret"
    return "ref"


def frame_accum(frames):
    mode = _kernel_mode()
    if mode == "ref":
        return _ref.frame_accum_ref(frames)
    return _fa_accum_kernel(frames, interpret=mode == "interpret")


def flash_attention(q, k, v, *, window: int = 0):
    mode = _kernel_mode()
    if mode == "ref":
        return _ref.flash_attention_ref(q, k, v, window=window)
    return _fa_kernel(q, k, v, window=window, interpret=mode == "interpret")


def ssm_scan(a, b):
    mode = _kernel_mode()
    if mode == "ref":
        return _ref.ssm_scan_ref(a, b)
    return _ssm_kernel(a, b, interpret=mode == "interpret")


def rglru_scan(a, b):
    mode = _kernel_mode()
    if mode == "ref":
        return _ref.rglru_scan_ref(a, b)
    return _rg_kernel(a, b, interpret=mode == "interpret")


def bfs_frontier(src, dst, sigma, dist, level):
    mode = _kernel_mode()
    if mode == "ref":
        return _ref.bfs_frontier_ref(src, dst, sigma, dist, level)
    return _bfs_kernel(src, dst, sigma, dist, level,
                       interpret=mode == "interpret")


def alias_draw(prob, alias, u1, u2):
    mode = _kernel_mode()
    if mode == "ref":
        return _ref.alias_draw_ref(prob, alias, u1, u2)
    return _alias_kernel(prob, alias, u1, u2, interpret=mode == "interpret")

"""Pallas kernel: state-frame accumulation (Alg. 2 line 27).

Accumulates W worker frames of n elements each — the Θ(T·n) hot spot of
CHECKFRAMES.  Tiling: the n axis is split into VMEM-resident blocks; each
grid step loads a (W, BLOCK_N) tile and tree-sums over W on the VPU.  The
frames are read linearly (the paper's favorable-access-pattern argument,
§3.3, survives on TPU: each tile is one contiguous DMA per worker row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(frames_ref, out_ref):
    # frames_ref: (W, BLOCK_N) in VMEM; out_ref: (BLOCK_N,)
    acc_t = (jnp.float32 if jnp.issubdtype(frames_ref.dtype, jnp.floating)
             else jnp.int32)
    out_ref[...] = jnp.sum(frames_ref[...].astype(acc_t), axis=0
                           ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def frame_accum(frames: jax.Array, *, block_n: int = 2048,
                interpret: bool = False) -> jax.Array:
    """frames: (W, n) → (n,) sum over workers."""
    W, n = frames.shape
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        frames = jnp.pad(frames, ((0, 0), (0, pad)))
    npad = n + pad
    out = pl.pallas_call(
        _kernel,
        grid=(npad // block_n,),
        in_specs=[pl.BlockSpec((W, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), frames.dtype),
        interpret=interpret,
    )(frames)
    return out[:n]

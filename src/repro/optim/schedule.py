"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak: float, warmup: int, total: int,
                    floor: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak * s / max(warmup, 1)
    t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup, warm, cos)

"""Gradient compression for slow (cross-pod / DCN) links.

int8 block-quantization with stochastic rounding + **error feedback**:
the residual of each quantization step is carried and added to the next
step's gradient, making the compression unbiased-in-the-limit (standard
EF-SGD construction).  Applied only to the ``pod`` axis reduction — ICI
all-reduces stay bf16/f32.

``compressed_psum`` accumulates int8 payloads in int32 (512 devices × 127
< 2³¹, no overflow), so hardware reduction still applies.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..core.compat import axis_size

PyTree = Any


def quantize_int8(x: jax.Array, key: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor scale, stochastic rounding. Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: PyTree, ef: PyTree, key: jax.Array,
                    axis_name: str) -> Tuple[PyTree, PyTree]:
    """psum(grads) over ``axis_name`` with int8 payload + error feedback.

    Returns (reduced f32 grads ≈ mean over axis, new error-feedback state).
    Scales are max-combined across the axis so the int8 grids agree.
    """
    world = axis_size(axis_name)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ef_leaves = jax.tree_util.tree_leaves(ef)
    keys = jax.random.split(key, len(leaves))
    out, new_ef = [], []
    for g, e, k in zip(leaves, ef_leaves, keys):
        gc = g.astype(jnp.float32) + e
        # agree on a shared scale (1 scalar all-reduce per tensor)
        local_max = jnp.maximum(jnp.max(jnp.abs(gc)), 1e-12)
        scale = jax.lax.pmax(local_max, axis_name) / 127.0
        noise = jax.random.uniform(k, g.shape, minval=-0.5, maxval=0.5)
        q = jnp.clip(jnp.round(gc / scale + noise), -127, 127)
        new_ef.append(gc - q * scale)                  # residual feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out.append(summed.astype(jnp.float32) * scale / world)
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_ef))

"""AdamW with ZeRO-1-friendly state layout.

State is a plain pytree mirroring the parameter tree (f32 moments), so the
sharding policy can assign it ZeRO-1 specs (sharded over the data axes) —
see ``launch/specs.opt_specs``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree,
                 cfg: AdamWConfig, lr: jax.Array | float | None = None
                 ) -> Tuple[PyTree, AdamWState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=mu, nu=nu), gnorm

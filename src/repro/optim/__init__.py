from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedule import cosine_schedule
from .compress import quantize_int8, dequantize_int8, compressed_psum
from .adaptive import AdaptiveAccumConfig, adaptive_accumulate

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "cosine_schedule", "quantize_int8", "dequantize_int8",
           "compressed_psum", "AdaptiveAccumConfig", "adaptive_accumulate"]

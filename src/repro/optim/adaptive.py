"""Adaptive gradient accumulation — the paper's ADS engine applied to
training (DESIGN.md §3.1).

SAMPLE() = one microbatch gradient; the frame holds (Σg, Σ‖g‖, Σ‖g‖², num);
CHECKFORSTOP = :class:`repro.core.stopping.GradVarianceCondition` (stop once
the relative standard error of the gradient-norm estimate is below target).
The accumulated Σg/num is exactly the gradient the optimizer consumes, so
adaptive accumulation composes with any optimizer.

This is a *device-level* loop (lax.while_loop), bounded by ``max_micro`` so
input data can be provisioned with a static shape; unconsumed microbatches
are wasted only if the condition stops early — the adaptive win is that easy
steps stop at ``min_micro`` while hard steps use the full budget.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from ..core.frames import StateFrame, combine
from ..core.stopping import GradVarianceCondition

PyTree = Any


# ---------------------------------------------------------------------------
# ADS-instance form (core/instances.GradVarianceInstance): estimate the mean
# per-example gradient norm of a FIXED model state over a fixed example
# population.  Norms are integer-quantized so frames reduce exactly under
# every strategy (the same trick as the wrs workload) and the exact oracle
# is a population mean, O(n).
# ---------------------------------------------------------------------------


def quantized_grad_norms(n_examples: int, dim: int, seed: int,
                         value_scale: int):
    """Per-example gradient norms of a linear-regression iterate, quantized
    to ``1 … value_scale−1`` (bounded away from 0 so the relative-SEM target
    is well-conditioned).  Returns (gq int32 (n,), exact mean of gq/scale).
    """
    import numpy as np
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n_examples, dim))
    w_true = rng.normal(size=(dim,))
    y = X @ w_true + 0.1 * rng.normal(size=n_examples)
    w = w_true + 0.5 * rng.normal(size=(dim,))     # a mid-training iterate
    g = (X @ w - y)[:, None] * X                   # ∇ of ½(x·w − y)² per row
    norms = np.linalg.norm(g, axis=1)
    norms = norms / norms.max()
    gq = np.maximum(1, np.round(norms * (value_scale - 1))).astype(np.int32)
    return gq, float(gq.mean()) / value_scale


def gradnorm_frame_template(n_examples: int, pad_to: int):
    return {"s1": jnp.zeros((), jnp.int32),
            "s2": jnp.zeros((), jnp.int32),
            "hits": jnp.zeros((pad_to,), jnp.int32)}


def make_gradnorm_sample_fn(gq, batch: int, pad_to: int):
    """SAMPLE(): draw ``batch`` example indices uniformly, accumulate the
    quantized norm moments Σgq, Σgq² plus per-example hit counts (the vector
    leaf that exercises SHARED_FRAME sharding)."""
    gq = jnp.asarray(gq, jnp.int32)
    n = gq.shape[0]

    def sample_fn(key, carry):
        idx = jax.random.randint(key, (batch,), 0, n)
        v = gq[idx]
        hits = jnp.zeros((pad_to,), jnp.int32).at[idx].add(1)
        data = {"s1": jnp.sum(v), "s2": jnp.sum(v * v), "hits": hits}
        return StateFrame(num=jnp.int32(batch), data=data), carry

    return sample_fn


@dataclasses.dataclass(frozen=True)
class AdaptiveAccumConfig:
    rtol: float = 0.25
    min_micro: int = 2
    max_micro: int = 16


def adaptive_accumulate(grad_fn: Callable[[PyTree, PyTree], Tuple[jax.Array, PyTree]],
                        params: PyTree, micro_batches: PyTree,
                        cfg: AdaptiveAccumConfig
                        ) -> Tuple[PyTree, jax.Array, jax.Array, jax.Array]:
    """micro_batches: pytree with leading dim ``max_micro``.

    Returns (mean grads, mean loss, n_micro_used, rel_sem).
    """
    cond = GradVarianceCondition(rtol=cfg.rtol, min_samples=cfg.min_micro,
                                 max_samples=cfg.max_micro)
    g_shapes = jax.eval_shape(
        lambda p, b: grad_fn(p, b)[1], params,
        jax.tree.map(lambda x: x[0], micro_batches))
    gsum0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), g_shapes)
    frame0 = StateFrame(num=jnp.int32(0),
                        data={"s1": jnp.zeros((), jnp.float32),
                              "s2": jnp.zeros((), jnp.float32)})

    def body(st):
        i, gsum, frame, loss_sum, stop = st
        batch = jax.tree.map(lambda x: x[i], micro_batches)
        loss, g = grad_fn(params, batch)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(g)))
        frame = combine(frame, StateFrame(
            num=jnp.int32(1), data={"s1": gn, "s2": jnp.square(gn)}))
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        stop, _ = cond(frame)
        return i + 1, gsum, frame, loss_sum + loss, stop

    def cond_fn(st):
        i, _, _, _, stop = st
        return jnp.logical_and(i < cfg.max_micro, ~stop)

    i, gsum, frame, loss_sum, _ = jax.lax.while_loop(
        cond_fn, body,
        (jnp.int32(0), gsum0, frame0, jnp.zeros((), jnp.float32),
         jnp.zeros((), bool)))
    n = jnp.maximum(i, 1).astype(jnp.float32)
    grads = jax.tree.map(lambda x: x / n, gsum)
    _, aux = cond(frame)
    return grads, loss_sum / n, i, aux["rel_sem"]

"""Adaptive gradient accumulation — the paper's ADS engine applied to
training (DESIGN.md §3.1).

SAMPLE() = one microbatch gradient; the frame holds (Σg, Σ‖g‖, Σ‖g‖², num);
CHECKFORSTOP = :class:`repro.core.stopping.GradVarianceCondition` (stop once
the relative standard error of the gradient-norm estimate is below target).
The accumulated Σg/num is exactly the gradient the optimizer consumes, so
adaptive accumulation composes with any optimizer.

This is a *device-level* loop (lax.while_loop), bounded by ``max_micro`` so
input data can be provisioned with a static shape; unconsumed microbatches
are wasted only if the condition stops early — the adaptive win is that easy
steps stop at ``min_micro`` while hard steps use the full budget.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from ..core.frames import StateFrame, combine
from ..core.stopping import GradVarianceCondition

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdaptiveAccumConfig:
    rtol: float = 0.25
    min_micro: int = 2
    max_micro: int = 16


def adaptive_accumulate(grad_fn: Callable[[PyTree, PyTree], Tuple[jax.Array, PyTree]],
                        params: PyTree, micro_batches: PyTree,
                        cfg: AdaptiveAccumConfig
                        ) -> Tuple[PyTree, jax.Array, jax.Array, jax.Array]:
    """micro_batches: pytree with leading dim ``max_micro``.

    Returns (mean grads, mean loss, n_micro_used, rel_sem).
    """
    cond = GradVarianceCondition(rtol=cfg.rtol, min_samples=cfg.min_micro,
                                 max_samples=cfg.max_micro)
    g_shapes = jax.eval_shape(
        lambda p, b: grad_fn(p, b)[1], params,
        jax.tree.map(lambda x: x[0], micro_batches))
    gsum0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), g_shapes)
    frame0 = StateFrame(num=jnp.int32(0),
                        data={"s1": jnp.zeros((), jnp.float32),
                              "s2": jnp.zeros((), jnp.float32)})

    def body(st):
        i, gsum, frame, loss_sum, stop = st
        batch = jax.tree.map(lambda x: x[i], micro_batches)
        loss, g = grad_fn(params, batch)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(g)))
        frame = combine(frame, StateFrame(
            num=jnp.int32(1), data={"s1": gn, "s2": jnp.square(gn)}))
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        stop, _ = cond(frame)
        return i + 1, gsum, frame, loss_sum + loss, stop

    def cond_fn(st):
        i, _, _, _, stop = st
        return jnp.logical_and(i < cfg.max_micro, ~stop)

    i, gsum, frame, loss_sum, _ = jax.lax.while_loop(
        cond_fn, body,
        (jnp.int32(0), gsum0, frame0, jnp.zeros((), jnp.float32),
         jnp.zeros((), bool)))
    n = jnp.maximum(i, 1).astype(jnp.float32)
    grads = jax.tree.map(lambda x: x / n, gsum)
    _, aux = cond(frame)
    return grads, loss_sum / n, i, aux["rel_sem"]

"""RG-LRU recurrent block (recurrentgemma-2b): gated linear recurrence +
GeGLU, sharing the linear-scan machinery with the Mamba block.

h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ u_t),
a_t = exp(−c · softplus(Λ) · σ(r_t)),  c = 8.

The paper's (Griffin) gate projections are block-diagonal; we use dense
projections of the same shape class (documented simplification — parameter
count within 2%).  Pallas kernel: ``kernels/rglru_scan``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamDef, rms_norm
from .ssm import causal_conv1d, linear_scan

_C = 8.0


def rglru_defs(cfg) -> dict:
    import math
    d, w, K = cfg.d_model, cfg.lru_width or cfg.d_model, 4
    res = 1.0 / math.sqrt(2.0 * max(cfg.n_layers, 1))
    return {
        "norm": ParamDef((d,), ("embed",), init="ones"),
        "w_in": ParamDef((d, w), ("embed", "lru"), init="scaled"),
        "w_gate": ParamDef((d, w), ("embed", "lru"), init="scaled"),
        "conv_w": ParamDef((K, w), (None, "lru"), init="scaled", scale=0.5),
        "conv_b": ParamDef((w,), ("lru",), init="zeros"),
        "w_r": ParamDef((w, w), ("lru_in", "lru"), init="scaled"),
        "b_r": ParamDef((w,), ("lru",), dtype=jnp.float32, init="zeros"),
        "w_i": ParamDef((w, w), ("lru_in", "lru"), init="scaled"),
        "b_i": ParamDef((w,), ("lru",), dtype=jnp.float32, init="zeros"),
        "lam": ParamDef((w,), ("lru",), dtype=jnp.float32, init="ones"),
        "w_out": ParamDef((w, d), ("lru", "embed"), init="scaled", scale=res),
    }


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, W) f32
    conv_tail: jax.Array  # (B, K−1, W)


def rglru_init_state(cfg, batch: int) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, w), jnp.float32),
                      conv_tail=jnp.zeros((batch, 3, w), jnp.bfloat16))


def _gates(p, u: jax.Array):
    r = jax.nn.sigmoid((u @ p["w_r"]).astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gate_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, gate_in * i * u.astype(jnp.float32)


def rglru_block(p, x: jax.Array, cfg,
                state: Optional[RGLRUState] = None,
                return_state: bool = False):
    """Full-sequence recurrent block. x: (B,S,d) → (B,S,d)."""
    h_in = rms_norm(x, p["norm"])
    u = h_in @ p["w_in"]                                     # (B,S,W)
    gate = jax.nn.gelu(h_in @ p["w_gate"])
    tail = state.conv_tail if state is not None else None
    u = causal_conv1d(u, p["conv_w"], p["conv_b"], tail)
    a, b = _gates(p, u)                                      # (B,S,W) f32
    h0 = state.h if state is not None else None
    hs = linear_scan(a, b, h0=h0, axis=1)                    # (B,S,W) f32
    y = hs.astype(x.dtype) * gate
    out = y @ p["w_out"]
    if not return_state:
        return x + out
    K = 4
    new_tail = jnp.concatenate([
        (state.conv_tail if state is not None else
         jnp.zeros((x.shape[0], K - 1, u.shape[-1]), x.dtype)),
        (h_in @ p["w_in"])], axis=1)[:, -(K - 1):, :]
    return x + out, RGLRUState(h=hs[:, -1], conv_tail=new_tail)


def rglru_decode_step(p, x: jax.Array, state: RGLRUState, cfg
                      ) -> Tuple[jax.Array, RGLRUState]:
    """One-token step. x: (B,d)."""
    h_in = rms_norm(x, p["norm"])
    u_raw = h_in @ p["w_in"]                                 # (B,W)
    gate = jax.nn.gelu(h_in @ p["w_gate"])
    window = jnp.concatenate([state.conv_tail, u_raw[:, None, :]], axis=1)
    u = jnp.sum(window.astype(jnp.float32)
                * p["conv_w"].astype(jnp.float32)[None], axis=1) \
        + p["conv_b"].astype(jnp.float32)
    u = u.astype(x.dtype)
    a, b = _gates(p, u)
    h = a * state.h + b
    y = h.astype(x.dtype) * gate
    out = y @ p["w_out"]
    return x + out, RGLRUState(h=h, conv_tail=window[:, 1:, :])

"""GQA attention: train/prefill (full or sliding-window, causal) + decode.

Three execution paths:

* ``attn_full``   — single-einsum masked attention (small S; smoke tests).
* ``attn_chunked``— flash-style streaming softmax over KV chunks per Q chunk,
                    O(S·chunk) live memory — the XLA path used by the dry-run
                    for long sequences.  (The Pallas TPU kernel
                    ``kernels/flash_attention`` implements the same math with
                    VMEM tiling and *causal block skipping*; this function is
                    its oracle.  The XLA path computes masked full rectangles:
                    ~2× causal FLOPs — called out in the roofline analysis.)
* ``attn_decode`` — one-token attention against a (possibly ring-buffered,
                    sequence-sharded) KV cache.  With the cache's S dimension
                    sharded over the ``model`` mesh axis, GSPMD lowers the
                    max/sum reductions to the flash-decoding collective
                    pattern (partial softmax + combine).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,KV,G,hd), k: (B,Sk,KV,hd) → (B,KV,G,Sq,Sk) (f32)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32)
    return s / jnp.sqrt(jnp.float32(hd))


def _causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """(…Sq,Sk) bool: k attends-able from q (causal ∧ window ∧ k valid)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def attn_full(q: jax.Array, k: jax.Array, v: jax.Array, *,
              window: int = 0, q_offset: int = 0) -> jax.Array:
    """(B,S,H,hd)×(B,S,KV,hd) GQA causal attention, materialized scores."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = _gqa_scores(qg, k)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    mask = _causal_mask(q_pos, k_pos, window)
    scores = jnp.where(mask, scores, NEG)
    att = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", att, v)
    return out.reshape(B, Sq, H, hd)


def attn_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 window: int = 0, chunk: int = 1024,
                 remat_inner: bool = True, unroll: bool = False) -> jax.Array:
    """Flash-style causal GQA with streaming softmax (oracle of the Pallas
    kernel).  Memory: O(B·H·chunk²) per block pair instead of O(B·H·S²).

    ``unroll=False`` (runtime path): lax.scan sweeps *all* KV chunks per Q
    chunk with masking (≈2× causal FLOPs; ``remat_inner`` recomputes the
    block softmax in backward so residuals stay O(S·hd) not O(S²)).

    ``unroll=True`` (roofline layer-differencing path): python loops with
    *static* causal/window block skipping — the FLOP/byte profile of the
    Pallas TPU kernel, visible to ``cost_analysis``.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if S % chunk != 0 or S <= chunk:
        return attn_full(q, k, v, window=window)
    nq = S // chunk
    import math
    scale = 1.0 / math.sqrt(hd)

    def block(q_blk, k_blk, v_blk, qi, kj, carry):
        m, l, acc = carry
        s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        q_pos = qi * chunk + jnp.arange(chunk)
        k_pos = kj * chunk + jnp.arange(chunk)
        mask = _causal_mask(q_pos, k_pos, window)              # (chunk, chunk)
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(q.dtype), v_blk,
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    if remat_inner and not unroll:
        block = jax.checkpoint(block)

    def init_carry():
        return (jnp.full((B, KV, G, chunk), NEG, jnp.float32),
                jnp.zeros((B, KV, G, chunk), jnp.float32),
                jnp.zeros((B, KV, G, chunk, hd), jnp.float32))

    if unroll:
        qs = q.reshape(B, nq, chunk, KV, G, hd)
        ks = k.reshape(B, nq, chunk, KV, hd)
        vs = v.reshape(B, nq, chunk, KV, hd)
        outs = []
        for qi in range(nq):
            carry = init_carry()
            for kj in range(nq):
                if kj > qi:               # static causal skip
                    continue
                if window > 0 and (kj + 1) * chunk <= qi * chunk - window:
                    continue              # static window skip
                carry = block(qs[:, qi], ks[:, kj], vs[:, kj], qi, kj, carry)
            m, l, acc = carry
            outs.append((acc / jnp.maximum(l, 1e-30)[..., None]
                         ).astype(q.dtype))
        out = jnp.stack(outs, axis=1)      # (B, nq, KV, G, chunk, hd)
        return out.transpose(0, 1, 4, 2, 3, 5).reshape(B, S, H, hd)

    # (nq, B, chunk, …) so scan iterates over blocks
    qc = q.reshape(B, nq, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nq, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nq, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    def q_block(_, inputs):
        qi, q_blk = inputs                      # q_blk: (B, chunk, KV, G, hd)

        def kv_step(carry, kv_inputs):
            kj, k_blk, v_blk = kv_inputs        # k_blk: (B, chunk, KV, hd)
            return block(q_blk, k_blk, v_blk, qi, kj, carry), None

        (m, l, acc), _ = jax.lax.scan(kv_step, init_carry(),
                                      (jnp.arange(nq), kc, vc))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, out                        # (B, KV, G, chunk, hd)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qc))
    # (nq, B, KV, G, chunk, hd) → (B, S, H, hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, hd)
    return out


def attn_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                k_pos: jax.Array, pos: jax.Array, *,
                window: int = 0) -> jax.Array:
    """One-token GQA attention against a cache.

    q: (B, H, hd); k_cache/v_cache: (B, Sc, KV, hd); ``k_pos``: (B, Sc)
    absolute positions stored in each cache slot (−1 ⇒ empty); ``pos``: (B,)
    current absolute position.  Ring-buffered SWA caches pass their slot→
    position map in ``k_pos`` so masking is layout-independent.
    """
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / jnp.sqrt(
                       jnp.float32(hd))
    valid = (k_pos >= 0) & (k_pos <= pos[:, None])
    if window > 0:
        valid &= k_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskh->bkgh", (p / jnp.maximum(l, 1e-30)
                                         ).astype(q.dtype), v_cache)
    return out.reshape(B, H, hd)

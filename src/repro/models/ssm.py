"""Mamba-1 selective SSM block (falcon-mamba-7b) + shared linear-recurrence
helpers (also used by the RG-LRU block).

Train/prefill use ``jax.lax.associative_scan`` over the sequence (log-depth,
TPU-friendly); decode advances the recurrence one step.  The Pallas kernel
``kernels/ssm_scan`` implements the chunked scan with VMEM tiling; the
functions here are its oracle.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamDef


def _assoc_scan(a: jax.Array, b: jax.Array, axis: int) -> jax.Array:
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=axis)
    return h


@jax.custom_vjp
def _linear_scan_cvjp(a: jax.Array, b: jax.Array) -> jax.Array:
    return _assoc_scan(a, b, 1)


def _ls_fwd(a, b):
    h = _assoc_scan(a, b, 1)
    return h, (a, h)


def _ls_bwd(res, g):
    # h_t = a_t h_{t−1} + b_t  ⇒  ∂L/∂b_t = γ_t with the *reverse* recurrence
    # γ_t = g_t + a_{t+1} γ_{t+1}; ∂L/∂a_t = γ_t · h_{t−1}.
    # Implemented as another associative scan (O(S) live memory — without
    # this custom vjp, differentiating associative_scan retains every
    # log-depth level: ~log₂(S)× the pair size; see EXPERIMENTS.md §Perf).
    a, h = res
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    def rev(x):
        return jnp.flip(x, axis=1)
    gamma = rev(_assoc_scan(rev(a_next), rev(g), 1))
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    return gamma * h_prev, gamma


_linear_scan_cvjp.defvjp(_ls_fwd, _ls_bwd)


def linear_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None,
                axis: int = 1) -> jax.Array:
    """h_t = a_t ⊙ h_{t−1} + b_t  along ``axis`` via associative scan.

    a, b: (..., S, ...) with the scan along ``axis``; returns all h_t.
    axis=1 uses a custom VJP whose backward is itself a reverse associative
    scan (memory O(S), not O(S·log S)).
    """
    if h0 is not None:
        # fold h0 into the first b: h_1 = a_1 h0 + b_1
        first = jax.lax.index_in_dim(b, 0, axis=axis, keepdims=True) + \
            jax.lax.index_in_dim(a, 0, axis=axis, keepdims=True) * \
            jnp.expand_dims(h0, axis)
        rest = jax.lax.slice_in_dim(b, 1, None, axis=axis)
        b = jnp.concatenate([first, rest], axis=axis)
    if axis == 1:
        return _linear_scan_cvjp(a, b)
    return _assoc_scan(a, b, axis)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (K,C), b (C,).

    ``tail`` (B,K−1,C) — previous context for decode/chunked prefill.
    Implemented as K shifted adds (K small: 4) — fusion-friendly.
    """
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)          # (B, S+K−1, C)
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        y = y + xp[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------

def mamba_defs(cfg) -> dict:
    import math
    d, di, N, dr, K = (cfg.d_model, cfg.dinner, cfg.ssm_state, cfg.dtrank,
                       cfg.ssm_conv)
    f32 = jnp.float32
    res = 1.0 / math.sqrt(2.0 * max(cfg.n_layers, 1))
    return {
        "norm": ParamDef((d,), ("embed",), init="ones"),
        "in_proj": ParamDef((d, 2 * di), ("embed", "inner2"), init="scaled"),
        "conv_w": ParamDef((K, di), (None, "inner"), init="scaled", scale=0.5),
        "conv_b": ParamDef((di,), ("inner",), init="zeros"),
        "x_proj": ParamDef((di, dr + 2 * N), ("inner", None), init="scaled"),
        "dt_proj": ParamDef((dr, di), (None, "inner"), init="scaled"),
        "dt_bias": ParamDef((di,), ("inner",), dtype=f32, init="zeros"),
        "A_log": ParamDef((di, N), ("inner", None), dtype=f32, init="ones"),
        "D": ParamDef((di,), ("inner",), dtype=f32, init="ones"),
        "out_proj": ParamDef((di, d), ("inner", "embed"), init="scaled", scale=res),
    }


class MambaState(NamedTuple):
    h: jax.Array         # (B, di, N) f32
    conv_tail: jax.Array  # (B, K−1, di)


def mamba_init_state(cfg, batch: int) -> MambaState:
    return MambaState(
        h=jnp.zeros((batch, cfg.dinner, cfg.ssm_state), jnp.float32),
        conv_tail=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.dinner), jnp.bfloat16))


def _ssm_inputs(p, x_c: jax.Array, cfg):
    """Common discretization: returns (a, b_in, C, x_c) with
    a, b: (B,S,di,N)."""
    dr, N = cfg.dtrank, cfg.ssm_state
    xdbl = x_c @ p["x_proj"]                                # (B,S,dr+2N)
    dt, Bc, Cc = jnp.split(xdbl, [dr, dr + N], axis=-1)
    dt = jax.nn.softplus(
        (dt @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])  # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (di,N)
    a = jnp.exp(dt[..., None] * A)                          # (B,S,di,N)
    b = (dt[..., None] * Bc[..., None, :].astype(jnp.float32)
         * x_c[..., None].astype(jnp.float32))              # (B,S,di,N)
    return a, b, Cc


def mamba_block(p, x: jax.Array, cfg,
                state: Optional[MambaState] = None,
                return_state: bool = False):
    """Full-sequence Mamba block. x: (B,S,d) → (B,S,d) (+ new state)."""
    from .layers import rms_norm
    h_in = rms_norm(x, p["norm"])
    xz = h_in @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    tail = state.conv_tail if state is not None else None
    x_c = jax.nn.silu(causal_conv1d(x_in, p["conv_w"], p["conv_b"], tail))
    a, b, Cc = _ssm_inputs(p, x_c, cfg)
    h0 = state.h if state is not None else None
    hs = linear_scan(a, b, h0=h0, axis=1)                   # (B,S,di,N) f32
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cc.astype(jnp.float32))
    y = y + p["D"] * x_c.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if not return_state:
        return x + out
    K = cfg.ssm_conv
    new_state = MambaState(
        h=hs[:, -1],
        conv_tail=jnp.concatenate([
            (state.conv_tail if state is not None else
             jnp.zeros((x.shape[0], K - 1, cfg.dinner), x.dtype)),
            x_in], axis=1)[:, -(K - 1):, :])
    return x + out, new_state


def mamba_decode_step(p, x: jax.Array, state: MambaState, cfg
                      ) -> Tuple[jax.Array, MambaState]:
    """One-token step. x: (B,d) → (B,d)."""
    from .layers import rms_norm
    h_in = rms_norm(x, p["norm"])
    xz = h_in @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                     # (B,di)
    # conv over [tail, x]
    K = cfg.ssm_conv
    window = jnp.concatenate([state.conv_tail, x_in[:, None, :]], axis=1)
    x_c = jnp.sum(window.astype(jnp.float32)
                  * p["conv_w"].astype(jnp.float32)[None], axis=1) \
        + p["conv_b"].astype(jnp.float32)
    x_c = jax.nn.silu(x_c).astype(x.dtype)                  # (B,di)
    a, b, Cc = _ssm_inputs(p, x_c[:, None, :], cfg)
    a, b, Cc = a[:, 0], b[:, 0], Cc[:, 0]                   # (B,di,N),(B,N)
    h = a * state.h + b
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
    y = y + p["D"] * x_c.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return x + out, MambaState(h=h, conv_tail=window[:, 1:, :])

"""Parameter definitions, initialization, and logical-axis sharding.

Every parameter is declared as a :class:`ParamDef` carrying *logical* axis
names (``"embed"``, ``"vocab"``, ``"heads"``, ``"ffn"``, ``"experts"``, …).
A :class:`ShardingRules` table maps logical axes to mesh axes with automatic
**divisibility fallback** (an axis that does not divide evenly is replicated
— e.g. smollm's 15 heads on a 16-way model axis), so a single policy serves
all ten architectures.  See DESIGN.md §3.2.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]     # one logical name (or None) per dim
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"                   # normal | zeros | ones | scaled
    scale: float = 1.0
    fan_in: int = 0                        # explicit contraction size for
                                           # "scaled" init (0 → shape[-2];
                                           # REQUIRED for 3-D projections
                                           # where shape[-2] is not the
                                           # contracted extent)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_leaf(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "scaled":  # truncated-normal fan-in scaling
        fan_in = d.fan_in or (d.shape[-2] if len(d.shape) >= 2
                              else d.shape[-1])
        std = d.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.truncated_normal(key, -2.0, 2.0, d.shape, jnp.float32)
                * std).astype(d.dtype)
    return (jax.random.normal(key, d.shape, jnp.float32) * d.scale * 0.02
            ).astype(d.dtype)


def init_params(defs: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis → tuple of mesh axes (applied with divisibility check)."""
    rules: Dict[str, Tuple[str, ...]]
    mesh_shape: Dict[str, int]

    def spec_for(self, d: ParamDef) -> P:
        return self.spec_for_shape(d.shape, d.logical)

    def spec_for_shape(self, shape: Sequence[int],
                       logical: Sequence[Optional[str]]) -> P:
        used: set = set()
        out = []
        for size, name in zip(shape, logical):
            axes = self.rules.get(name, ()) if name else ()
            chosen = []
            prod = 1
            for ax in axes:
                if ax in used:
                    continue
                a = self.mesh_shape.get(ax, 1)
                if a > 1 and size % (prod * a) == 0:
                    chosen.append(ax)
                    prod *= a
            for ax in chosen:
                used.add(ax)
            out.append(tuple(chosen) if len(chosen) > 1
                       else (chosen[0] if chosen else None))
        return P(*out)

    def constrain(self, x: jax.Array,
                  logical: Sequence[Optional[str]]) -> jax.Array:
        """with_sharding_constraint by logical names (no-op off-mesh).

        If divisibility fallback empties the spec entirely, *skip* the
        constraint rather than pinning the tensor replicated — an all-None
        spec is a hard replication constraint under GSPMD and can force
        giant activation all-gathers (see EXPERIMENTS.md §Perf, mixtral)."""
        try:
            spec = self.spec_for_shape(x.shape, logical)
            if all(s is None for s in spec):
                return x
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            return x


def param_specs(defs: PyTree, rules: ShardingRules) -> PyTree:
    return jax.tree.map(lambda d: rules.spec_for(d), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def shardings_for(defs: PyTree, rules: ShardingRules, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda d: NamedSharding(mesh, rules.spec_for(d)), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


def rotary(q: jax.Array, k: jax.Array, positions: jax.Array,
           theta: float = 10_000.0) -> Tuple[jax.Array, jax.Array]:
    """RoPE applied to (..., S, H, hd) q/k given (..., S) positions."""
    hd = q.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                     # (..., S, 1, half)
    sin = sin[..., None, :]

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        return jnp.concatenate(
            [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)

    return rot(q), rot(k)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
           constrain: Callable[[jax.Array], jax.Array] = lambda x: x
           ) -> jax.Array:
    h = constrain(jax.nn.silu(x @ w1) * (x @ w3))
    return h @ w2


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          vocab: int) -> jax.Array:
    """Token-mean CE on (…, V_padded) logits; labels ≥ vocab are masked.

    Works with vocab-sharded logits: the max/sum reductions lower to
    all-reduces under GSPMD.
    """
    lf = logits.astype(jnp.float32)
    # mask padded vocab tail — elementwise (iota < vocab), NOT .at[].set:
    # a dynamic-update-slice across the vocab-sharded dim would force GSPMD
    # to gather the full logits (67 GB f32 for seamless; see §Perf)
    if lf.shape[-1] > vocab:
        mask = jnp.arange(lf.shape[-1]) < vocab
        lf = jnp.where(mask, lf, -1e30)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    valid = (labels >= 0) & (labels < vocab)
    ce = jnp.where(valid, lse - picked, 0.0)
    return jnp.sum(ce) / jnp.maximum(jnp.sum(valid), 1)

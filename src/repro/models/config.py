"""Model/shape configuration for every assigned architecture.

``ModelConfig`` covers the five architecture families uniformly
(dense / moe / ssm / hybrid / encdec / vlm share the decoder substrate);
``ShapeConfig`` is one of the four assigned input shapes.  Concrete configs
live in ``repro/configs/<arch>.py`` and register themselves here.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 → d_model // n_heads
    window: int = 0                # 0 → full attention; >0 → sliding window
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_ff: int = 0                # per-expert ff width (0 → d_ff)
    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    dt_rank: int = 0               # 0 → ceil(d_model/16)
    d_inner: int = 0               # 0 → 2·d_model
    # hybrid (recurrentgemma): pattern unit (rec, rec, attn); lru width
    lru_width: int = 0
    attn_every: int = 0            # every k-th layer is attention (rg: 3)
    local_window: int = 0          # rg local-attention window
    # enc-dec (seamless): encoder depth; frontend stub emits frame embeddings
    enc_layers: int = 0
    frame_ratio: int = 4           # encoder frames = seq // frame_ratio
    # vlm: patch embeddings prepended (stub frontend)
    n_patches: int = 0
    # numerics / memory knobs (hillclimbing surface)
    dtype: str = "bfloat16"
    remat: str = "full"            # none | dots | full
    scan_layers: bool = True       # False → python-unrolled (used by the
                                   # roofline's layer-differencing compiles)
    grad_accum: int = 1            # microbatches per train step
    attn_chunk: int = 1024         # flash-style q/kv block in the XLA path
    vocab_pad_to: int = 128
    tie_embeddings: bool = False
    capacity_factor: float = 1.25  # MoE token-dropping capacity
    moe_dispatch: str = "onehot"   # onehot (GShard-faithful baseline) |
                                   # sort (gather/scatter — §Perf hillclimb)
    moe_group: int = 512           # tokens per dispatch group

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p

    @property
    def dinner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dtrank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM / hybrid / SWA.)"""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def param_count(self) -> int:
        """Closed-form parameter count (for MODEL_FLOPS and reporting)."""
        d, hd = self.d_model, self.hd
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, N, dr = self.dinner, self.ssm_state, self.dtrank
            per = (d * 2 * di            # in_proj
                   + di * self.ssm_conv  # depthwise conv
                   + di * (dr + 2 * N)   # x_proj
                   + dr * di + di        # dt_proj
                   + di * N + di         # A_log, D
                   + di * d              # out_proj
                   + d)                  # norm
            return emb + self.n_layers * per
        attn = d * (self.n_heads * hd) + d * (self.n_kv * hd) * 2 \
            + (self.n_heads * hd) * d
        if self.family == "moe":
            ff_w = self.moe_ff or self.d_ff
            mlp = self.n_experts * 3 * d * ff_w + d * self.n_experts  # + router
        else:
            mlp = 3 * d * self.d_ff
        per = attn + mlp + 2 * d
        if self.family == "hybrid":
            w = self.lru_width or d
            rec = (d * 2 * w + w * self.ssm_conv + 2 * w * 2  # gates (low-rank-ish, full here)
                   + w * 2 * w + w + w * d)
            n_attn = self.n_layers // (self.attn_every or 3)
            n_rec = self.n_layers - n_attn
            return emb + n_attn * per + n_rec * (rec + 3 * d * self.d_ff + 2 * d)
        total = self.n_layers * per
        if self.family == "encdec":
            # encoder layers (self-attn + mlp) + decoder cross-attn
            enc = self.enc_layers * (attn + 3 * d * self.d_ff + 2 * d)
            total += enc + self.n_layers * (attn + d)
        return emb + total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d = self.d_model
        ff_w = self.moe_ff or self.d_ff
        dense_moe = self.n_experts * 3 * d * ff_w
        active_moe = self.top_k * 3 * d * ff_w
        return self.param_count() - self.n_layers * (dense_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    import importlib
    import pkgutil
    import repro.configs as pkg
    for m in pkgutil.iter_modules(pkg.__path__):
        importlib.import_module(f"repro.configs.{m.name}")


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch × shape) is a runnable cell (DESIGN.md §4 skip rules)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k skipped: pure full-attention arch (O(S²) KV)"
    return True, ""

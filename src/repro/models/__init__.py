from .config import (ModelConfig, ShapeConfig, SHAPES, get_config,
                     all_configs, register, cell_is_applicable)
from .transformer import Model

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config",
           "all_configs", "register", "cell_is_applicable", "Model"]

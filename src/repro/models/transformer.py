"""Model assembly for all ten assigned architectures.

One :class:`Model` drives five families off a shared decoder substrate:

* ``dense``  — GQA decoder (mistral-large, internlm2, h2o-danube (SWA),
               smollm)
* ``moe``    — GQA decoder with MoE FFN (mixtral (SWA), qwen3-moe)
* ``ssm``    — Mamba-1 stack, attention-free (falcon-mamba)
* ``hybrid`` — RG-LRU ⊕ local attention, pattern (rec, rec, attn)
               (recurrentgemma)
* ``encdec`` — encoder–decoder with cross-attention; audio frontend stubbed
               as precomputed frame embeddings (seamless-m4t)
* ``vlm``    — decoder with prepended patch embeddings; ViT frontend stubbed
               (internvl2)

Everything is scan-over-layers (compile-time O(1) in depth) with
configurable remat.  The functional API is

    train_loss(params, batch)                 → scalar loss
    prefill(params, batch)                    → (cache, last_logits)
    decode_step(params, cache, batch)         → (cache', logits)

``batch`` layouts per family are produced by ``launch/specs.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import attn_chunked, attn_decode, attn_full
from .config import ModelConfig
from .layers import (ParamDef, init_params, abstract_params, rms_norm, rotary,
                     softmax_cross_entropy, swiglu)
from .moe import moe_defs, moe_ffn
from .rglru import RGLRUState, rglru_block, rglru_decode_step, rglru_defs
from .ssm import MambaState, mamba_block, mamba_decode_step, mamba_defs

PyTree = Any


def _stack_defs(defs: PyTree, n: int) -> PyTree:
    """Prepend a stacked ``layers`` dim to every ParamDef (scan weights)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.logical,
                           dtype=d.dtype, init=d.init, scale=d.scale,
                           fan_in=d.fan_in or (d.shape[-2]
                                               if len(d.shape) >= 2
                                               else d.shape[-1])),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    """3-D head-major projections: divisibility fallback must check the
    HEAD COUNT (smollm's 15, rg's 10), not the fused H·hd dim.

    Residual-branch outputs (wo, and w2 in _mlp_defs) are scaled by
    1/√(2L) (GPT-2 init): without it the per-layer backward Jacobian
    exceeds 1 and gradients grow ~2^L with depth (observed: gnorm 5e6 at
    L=12 with varied tokens — tests/test_models_smoke.py guards this)."""
    import math
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    res = 1.0 / math.sqrt(2.0 * max(cfg.n_layers, 1))
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wq": ParamDef((d, H, hd), ("embed", "heads", None), init="scaled",
                       fan_in=d),
        "wk": ParamDef((d, KV, hd), ("embed", "kv", None), init="scaled",
                       fan_in=d),
        "wv": ParamDef((d, KV, hd), ("embed", "kv", None), init="scaled",
                       fan_in=d),
        "wo": ParamDef((H, hd, d), ("heads", None, "embed"), init="scaled",
                       scale=res, fan_in=H * hd),
    }


def _mlp_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    import math
    d, ff = cfg.d_model, cfg.d_ff
    res = 1.0 / math.sqrt(2.0 * max(cfg.n_layers, 1))
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "w1": ParamDef((d, ff), ("embed", "ffn"), init="scaled"),
        "w3": ParamDef((d, ff), ("embed", "ffn"), init="scaled"),
        "w2": ParamDef((ff, d), ("ffn", "embed"), init="scaled", scale=res),
    }


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rules: Any = None  # ShardingRules | None

    # ------------------------------------------------------------------ defs
    def _layer_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.family == "ssm":
            return mamba_defs(cfg)
        base = {"attn": _attn_defs(cfg)}
        if cfg.family == "moe":
            base["moe"] = moe_defs(cfg)
        else:
            base["mlp"] = _mlp_defs(cfg)
        return base

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d, Vp = cfg.d_model, cfg.padded_vocab
        out: Dict[str, Any] = {
            "embed": ParamDef((Vp, d), ("vocab", "embed"), init="normal"),
            "final_norm": ParamDef((d,), ("embed",), init="ones"),
        }
        if not cfg.tie_embeddings:
            out["unembed"] = ParamDef((d, Vp), ("embed", "vocab"),
                                      init="scaled")
        if cfg.family == "hybrid":
            unit = {
                "r0": rglru_defs(cfg), "r0_mlp": _mlp_defs(cfg),
                "r1": rglru_defs(cfg), "r1_mlp": _mlp_defs(cfg),
                "a": _attn_defs(cfg), "a_mlp": _mlp_defs(cfg),
            }
            n_units = cfg.n_layers // 3
            rem = cfg.n_layers - 3 * n_units
            out["units"] = _stack_defs(unit, n_units)
            for i in range(rem):
                out[f"tail_r{i}"] = rglru_defs(cfg)
                out[f"tail_r{i}_mlp"] = _mlp_defs(cfg)
        elif cfg.family == "encdec":
            enc_layer = {"attn": _attn_defs(cfg), "mlp": _mlp_defs(cfg)}
            dec_layer = {"attn": _attn_defs(cfg), "cross": _attn_defs(cfg),
                         "mlp": _mlp_defs(cfg)}
            out["enc_layers"] = _stack_defs(enc_layer, cfg.enc_layers)
            out["enc_norm"] = ParamDef((d,), ("embed",), init="ones")
            out["dec_layers"] = _stack_defs(dec_layer, cfg.n_layers)
        else:
            out["layers"] = _stack_defs(self._layer_defs(), cfg.n_layers)
        return out

    def init(self, key: jax.Array) -> PyTree:
        return init_params(self.param_defs(), key)

    def abstract(self) -> PyTree:
        return abstract_params(self.param_defs())

    # ------------------------------------------------------------ helpers
    def _constrain(self, x, logical):
        if self.rules is None:
            return x
        return self.rules.constrain(x, logical)

    def _remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        if self.cfg.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return jax.checkpoint(fn)

    def _scan(self, fn, carry, xs):
        """lax.scan over stacked layer params — or a python unroll when
        ``cfg.scan_layers`` is False (roofline layer-differencing compiles,
        where while-loop bodies would be cost-counted only once)."""
        if self.cfg.scan_layers:
            return jax.lax.scan(fn, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            sl = jax.tree.map(lambda a: a[i], xs)
            carry, y = fn(carry, sl)
            ys.append(y)
        if not ys or ys[0] is None:
            return carry, None
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        return carry, stacked

    # -------------------------------------------------------- sublayers
    def _attn_seq(self, p, x, positions, window: int, causal: bool = True):
        cfg = self.cfg
        B, S, _ = x.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
        h = rms_norm(x, p["ln"])
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        q, k = rotary(q, k, positions)
        q = self._constrain(q, ("batch", None, "heads_act", None))
        if not causal:
            # bidirectional (encoder): streamed softmax for long frames
            o = _attn_bidir(q, k, v, chunk=cfg.attn_chunk
                            if cfg.scan_layers else 0)
        elif S > cfg.attn_chunk:
            # unrolled (static block-skip) when layers are unrolled too —
            # the roofline diff path; see attn_chunked docstring
            unroll = not cfg.scan_layers
            chunk = cfg.attn_chunk
            if unroll:  # cap block count so diff compiles stay small
                while S // chunk > 8:
                    chunk *= 2
            o = attn_chunked(q, k, v, window=window, chunk=chunk,
                             remat_inner=cfg.remat != "none", unroll=unroll)
        else:
            o = attn_full(q, k, v, window=window)
        return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])

    def _cross_seq(self, p, x, mem, positions):
        cfg = self.cfg
        B, S, _ = x.shape
        Sm = mem.shape[1]
        H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
        h = rms_norm(x, p["ln"])
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
        o = _attn_bidir(q, k, v, chunk=cfg.attn_chunk
                        if cfg.scan_layers else 0)
        return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])

    def _mlp(self, p, x):
        h = rms_norm(x, p["ln"])
        y = swiglu(h, p["w1"], p["w3"], p["w2"],
                   constrain=lambda t: self._constrain(
                       t, ("batch", None, "ffn_act")))
        return x + y

    def _moe(self, p, x):
        h = rms_norm(x, p["ln"])
        y, aux = moe_ffn(p, h, self.cfg, constrain=self._constrain)
        return x + y, aux

    # ------------------------------------------------------- backbone (seq)
    def backbone(self, params, x, positions) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward. x: (B,S,d) embedded → (B,S,d), aux_loss."""
        cfg = self.cfg

        if cfg.family == "ssm":
            def layer(xc, lp):
                xc = mamba_block(lp, xc, cfg)
                xc = self._constrain(xc, ("batch", "seq_sp", None))
                return xc, jnp.zeros((), jnp.float32)
        elif cfg.family == "moe":
            def layer(xc, lp):
                xc = self._attn_seq(lp["attn"], xc, positions, cfg.window)
                xc, aux = self._moe(lp["moe"], xc)
                xc = self._constrain(xc, ("batch", "seq_sp", None))
                return xc, aux
        elif cfg.family == "hybrid":
            def unit(xc, lp):
                for r in ("r0", "r1"):
                    xc = rglru_block(lp[r], xc, cfg)
                    xc = self._mlp(lp[f"{r}_mlp"], xc)
                xc = self._attn_seq(lp["a"], xc, positions, cfg.local_window)
                xc = self._mlp(lp["a_mlp"], xc)
                xc = self._constrain(xc, ("batch", "seq_sp", None))
                return xc, jnp.zeros((), jnp.float32)
            xc, auxs = self._scan(self._remat(unit), x, params["units"])
            aux = jnp.sum(auxs)
            i = 0
            while f"tail_r{i}" in params:
                xc = rglru_block(params[f"tail_r{i}"], xc, cfg)
                xc = self._mlp(params[f"tail_r{i}_mlp"], xc)
                i += 1
            return xc, aux
        else:  # dense / vlm decoder
            def layer(xc, lp):
                xc = self._attn_seq(lp["attn"], xc, positions, cfg.window)
                xc = self._mlp(lp["mlp"], xc)
                xc = self._constrain(xc, ("batch", "seq_sp", None))
                return xc, jnp.zeros((), jnp.float32)

        xc, auxs = self._scan(self._remat(layer), x, params["layers"])
        return xc, jnp.sum(auxs)

    def _encoder(self, params, frames) -> jax.Array:
        """Bidirectional encoder over precomputed frame embeddings."""
        cfg = self.cfg
        B, Sm, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(Sm), (B, Sm))

        def layer(xc, lp):
            xc = self._attn_seq(lp["attn"], xc, positions, 0, causal=False)
            xc = self._mlp(lp["mlp"], xc)
            return xc, None

        x, _ = self._scan(self._remat(layer), frames, params["enc_layers"])
        return rms_norm(x, params["enc_norm"])

    def _decoder_ed(self, params, x, mem, positions) -> jax.Array:
        def layer(xc, lp):
            xc = self._attn_seq(lp["attn"], xc, positions, self.cfg.window)
            xc = self._cross_seq(lp["cross"], xc, mem, positions)
            xc = self._mlp(lp["mlp"], xc)
            return xc, None

        x, _ = self._scan(self._remat(layer), x, params["dec_layers"])
        return x

    # ------------------------------------------------------------- embed/out
    def _embed_batch(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """→ (x (B,S,d), positions (B,S))."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype)      # (B,P,d)
            x = jnp.concatenate([patches, x], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self._constrain(x, ("batch", "seq_sp", None))
        return x, positions

    def _logits(self, params, x) -> jax.Array:
        if self.cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["unembed"]

    # ------------------------------------------------------------ train loss
    def train_loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "encdec":
            mem = self._encoder(params, batch["frames"].astype(jnp.bfloat16))
            x, positions = self._embed_batch(params, batch)
            x = self._decoder_ed(params, x, mem, positions)
            aux = jnp.zeros((), jnp.float32)
        else:
            x, positions = self._embed_batch(params, batch)
            x, aux = self.backbone(params, x, positions)
        x = rms_norm(x, params["final_norm"])
        labels = batch["labels"]
        if cfg.family == "vlm":  # only text positions carry labels
            x = x[:, -labels.shape[1]:]
        logits = self._logits(params, x)
        loss = softmax_cross_entropy(logits, labels, cfg.vocab)
        return loss + 0.01 * aux

    # --------------------------------------------------------------- caches
    def init_cache(self, batch_size: int, capacity: int) -> PyTree:
        cfg = self.cfg
        KV, hd = cfg.n_kv, cfg.hd
        bf = jnp.bfloat16
        if cfg.family == "ssm":
            return {
                "h": jnp.zeros((cfg.n_layers, batch_size, cfg.dinner,
                                cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch_size,
                                   cfg.ssm_conv - 1, cfg.dinner), bf),
            }
        if cfg.family == "hybrid":
            n_units = cfg.n_layers // 3
            rem = cfg.n_layers - 3 * n_units
            w = cfg.lru_width or cfg.d_model
            sc = min(capacity, cfg.local_window)
            return {
                "h": jnp.zeros((n_units, 2, batch_size, w), jnp.float32),
                "conv": jnp.zeros((n_units, 2, batch_size, 3, w), bf),
                "k": jnp.zeros((n_units, batch_size, sc, KV, hd), bf),
                "v": jnp.zeros((n_units, batch_size, sc, KV, hd), bf),
                "kpos": jnp.full((batch_size, sc), -1, jnp.int32),
                "tail_h": jnp.zeros((max(rem, 1), batch_size, w), jnp.float32),
                "tail_conv": jnp.zeros((max(rem, 1), batch_size, 3, w), bf),
            }
        sc = min(capacity, cfg.window) if cfg.window else capacity
        n_l = cfg.n_layers
        cache = {
            "k": jnp.zeros((n_l, batch_size, sc, KV, hd), bf),
            "v": jnp.zeros((n_l, batch_size, sc, KV, hd), bf),
            "kpos": jnp.full((batch_size, sc), -1, jnp.int32),
        }
        if cfg.family == "encdec":
            sm = capacity // cfg.frame_ratio
            cache["cross_k"] = jnp.zeros((n_l, batch_size, sm, KV, hd), bf)
            cache["cross_v"] = jnp.zeros((n_l, batch_size, sm, KV, hd), bf)
        return cache

    # ---------------------------------------------------------- decode step
    def _attn_dec(self, p, x, k_cache, v_cache, kpos, pos, window):
        """x: (B,d); caches (B,Sc,KV,hd); returns (x', k', v')."""
        cfg = self.cfg
        B = x.shape[0]
        H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
        Sc = k_cache.shape[1]
        h = rms_norm(x, p["ln"])
        q = jnp.einsum("bd,dhk->bhk", h, p["wq"])[:, None]
        k = jnp.einsum("bd,dhk->bhk", h, p["wk"])[:, None]
        v = jnp.einsum("bd,dhk->bhk", h, p["wv"])[:, None]
        q, k = rotary(q, k, pos[:, None])
        q, k = q[:, 0], k[:, 0]
        slot = jnp.where(window > 0, pos % Sc, jnp.minimum(pos, Sc - 1))
        onehot = jax.nn.one_hot(slot, Sc, dtype=k_cache.dtype)  # (B,Sc)
        k_cache = k_cache * (1 - onehot)[..., None, None] \
            + k[:, None] * onehot[..., None, None]
        v_cache = v_cache * (1 - onehot)[..., None, None] \
            + v[:, 0][:, None] * onehot[..., None, None]
        o = attn_decode(q, k_cache, v_cache, kpos, pos, window=window)
        o = o.reshape(B, H, hd)
        return (x + jnp.einsum("bhk,hkd->bd", o, p["wo"]), k_cache, v_cache)

    def _mlp_dec(self, p, x):
        h = rms_norm(x, p["ln"])
        return x + swiglu(h, p["w1"], p["w3"], p["w2"])

    def _moe_dec(self, p, x):
        """MoE FFN for a single-token batch (B,d)."""
        h = rms_norm(x, p["ln"])
        y, _ = moe_ffn(p, h[:, None, :], self.cfg,
                       constrain=self._constrain,
                       group_size=x.shape[0])
        return x + y[:, 0]

    def decode_step(self, params, cache, batch) -> Tuple[PyTree, jax.Array]:
        """One token for every sequence. batch = {"tokens": (B,), "pos": (B,)}."""
        cfg = self.cfg
        tokens, pos = batch["tokens"], batch["pos"]
        x = params["embed"][tokens]                          # (B,d)
        new_cache = dict(cache)

        if cfg.family == "ssm":
            def layer(xc, lp_state):
                lp, h, conv = lp_state
                xc, st = mamba_decode_step(
                    lp, xc, MambaState(h=h, conv_tail=conv), cfg)
                return xc, (st.h, st.conv_tail)
            x, (hs, convs) = self._scan(
                layer, x, (params["layers"], cache["h"], cache["conv"]))
            new_cache.update(h=hs, conv=convs)

        elif cfg.family == "hybrid":
            Sc = cache["k"].shape[2]
            slot = pos % Sc
            kpos = _update_kpos(cache["kpos"], slot, pos)

            def unit(xc, xs):
                lp, h2, conv2, kc, vc = xs
                outs_h, outs_c = [], []
                for i, r in enumerate(("r0", "r1")):
                    st = RGLRUState(h=h2[i], conv_tail=conv2[i])
                    xc, st = rglru_decode_step(lp[r], xc, st, cfg)
                    xc = self._mlp_dec(lp[f"{r}_mlp"], xc)
                    outs_h.append(st.h)
                    outs_c.append(st.conv_tail)
                xc, kc, vc = self._attn_dec(lp["a"], xc, kc, vc, kpos, pos,
                                            cfg.local_window)
                xc = self._mlp_dec(lp["a_mlp"], xc)
                return xc, (jnp.stack(outs_h), jnp.stack(outs_c), kc, vc)

            x, (hs, convs, ks, vs) = self._scan(
                unit, x, (params["units"], cache["h"], cache["conv"],
                          cache["k"], cache["v"]))
            new_cache.update(h=hs, conv=convs, k=ks, v=vs, kpos=kpos)
            th, tc = [], []
            i = 0
            while f"tail_r{i}" in params:
                st = RGLRUState(h=cache["tail_h"][i],
                                conv_tail=cache["tail_conv"][i])
                x, st = rglru_decode_step(params[f"tail_r{i}"], x, st, cfg)
                x = self._mlp_dec(params[f"tail_r{i}_mlp"], x)
                th.append(st.h)
                tc.append(st.conv_tail)
                i += 1
            if th:
                new_cache.update(tail_h=jnp.stack(th), tail_conv=jnp.stack(tc))

        else:  # dense / moe / vlm / encdec decoders
            Sc = cache["k"].shape[2]
            slot = jnp.where(cfg.window > 0, pos % Sc, jnp.minimum(pos, Sc - 1))
            kpos = _update_kpos(cache["kpos"], slot, pos)
            is_ed = cfg.family == "encdec"

            def layer(xc, xs):
                if is_ed:
                    lp, kc, vc, xk, xv = xs
                else:
                    lp, kc, vc = xs
                xc, kc, vc = self._attn_dec(lp["attn"], xc, kc, vc, kpos, pos,
                                            cfg.window)
                if is_ed:
                    xc = _cross_dec(self, lp["cross"], xc, xk, xv)
                if cfg.family == "moe":
                    xc = self._moe_dec(lp["moe"], xc)
                else:
                    xc = self._mlp_dec(lp["mlp"], xc)
                return xc, (kc, vc)

            xs = (params["dec_layers" if is_ed else "layers"],
                  cache["k"], cache["v"])
            if is_ed:
                xs = xs + (cache["cross_k"], cache["cross_v"])
            x, (ks, vs) = self._scan(layer, x, xs)
            new_cache.update(k=ks, v=vs, kpos=kpos)

        x = rms_norm(x, params["final_norm"])
        logits = self._logits(params, x)
        return new_cache, logits

    # -------------------------------------------------------------- prefill
    def prefill(self, params, batch) -> Tuple[PyTree, jax.Array]:
        """Process a full prompt; emit cache + last-position logits.

        For the dry-run the cache is rebuilt by re-running layer projections
        (ssm/hybrid keep final states; attention keeps K/V).  Implemented as
        the full-sequence backbone with per-layer K/V captured via scan ys.
        """
        cfg = self.cfg
        if cfg.family == "encdec":
            mem = self._encoder(params, batch["frames"].astype(jnp.bfloat16))
            x, positions = self._embed_batch(params, batch)
            x = self._decoder_ed(params, x, mem, positions)
            xl = rms_norm(x[:, -1], params["final_norm"])
            return {}, self._logits(params, xl)
        x, positions = self._embed_batch(params, batch)
        x, _ = self.backbone(params, x, positions)
        xl = rms_norm(x[:, -1], params["final_norm"])
        return {}, self._logits(params, xl)


def _update_kpos(kpos: jax.Array, slot: jax.Array, pos: jax.Array) -> jax.Array:
    onehot = jax.nn.one_hot(slot, kpos.shape[1], dtype=jnp.int32)
    return kpos * (1 - onehot) + pos[:, None] * onehot


def _cross_dec(model: Model, p, x, xk, xv):
    cfg = model.cfg
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    h = rms_norm(x, p["ln"])
    q = jnp.einsum("bd,dhk->bhk", h, p["wq"])
    Sm = xk.shape[1]
    kpos = jnp.broadcast_to(jnp.arange(Sm), (B, Sm))
    o = attn_decode(q, xk, xv, kpos, jnp.full((B,), Sm, jnp.int32))
    return x + jnp.einsum("bhk,hkd->bd", o.reshape(B, H, hd), p["wo"])


def _attn_bidir(q, k, v, chunk: int = 0):
    """Non-causal attention (encoder / cross).  ``chunk > 0`` streams KV
    blocks with a running softmax (O(Sq·chunk) live scores instead of
    O(Sq·Sk)) — the flash pattern without masks."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Sk = k.shape[1]
    qg = q.reshape(B, Sq, KV, G, hd)
    if chunk and Sk > chunk and Sk % chunk == 0:
        import math
        scale = 1.0 / math.sqrt(hd)
        nk = Sk // chunk
        kc = k.reshape(B, nk, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(B, nk, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

        @jax.checkpoint
        def step(carry, kv):
            m, l, acc = carry
            k_blk, v_blk = kv
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_blk,
                           preferred_element_type=jnp.float32) * scale
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(q.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, Sq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(
                       jnp.float32(hd))
    att = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", att, v)
    return o.reshape(B, Sq, H, hd)

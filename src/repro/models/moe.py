"""Mixture-of-Experts FFN (mixtral-8x22b, qwen3-moe) — GShard-style dense
dispatch with capacity, grouped to bound the one-hot tensors.

Sharding strategy is chosen per arch by divisibility (DESIGN.md §3.2):
* qwen3 (128 experts, 16-way model axis) → **EP**: experts sharded over
  ``model``; the dispatch einsum induces the all-to-all.
* mixtral (8 experts, 16-way model axis) → **TP-MoE**: experts replicated,
  per-expert ffn dim sharded over ``model`` (classic Megatron within expert).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import ParamDef


def moe_defs(cfg) -> dict:
    import math
    d = cfg.d_model
    E = cfg.n_experts
    ff = cfg.moe_ff or cfg.d_ff
    res = 1.0 / math.sqrt(2.0 * max(cfg.n_layers, 1))
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "router": ParamDef((d, E), ("embed", None), dtype=jnp.float32,
                           init="scaled"),
        "w1": ParamDef((E, d, ff), ("experts", "embed", "ffn"), init="scaled"),
        "w3": ParamDef((E, d, ff), ("experts", "embed", "ffn"), init="scaled"),
        "w2": ParamDef((E, ff, d), ("experts", "ffn", "embed"), init="scaled", scale=res),
    }


def moe_capacity(cfg, tokens_per_group: int) -> int:
    cap = int(tokens_per_group * cfg.top_k / cfg.n_experts
              * cfg.capacity_factor) + 1
    # round up to a lane-friendly multiple
    return max(8, ((cap + 7) // 8) * 8)


def route_topk(logits: jax.Array, k: int, capacity: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Token-choice top-k routing with per-expert capacity.

    logits: (G, S, E) f32 →
      dispatch (G, S, E, C) one-hot, combine (G, S, E, C) weights,
      aux_loss (load-balancing, Switch-style).
    Tokens overflowing an expert's capacity are dropped for that expert
    (standard GShard semantics).
    """
    G, S, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                    # (G,S,k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert queue: process
    # choice ranks in order, tokens in sequence order (deterministic).
    oh = jax.nn.one_hot(topi, E, dtype=jnp.float32)         # (G,S,k,E)
    # flatten (k-major within token? choice rank 0 of all tokens first):
    ohf = oh.transpose(0, 2, 1, 3).reshape(G, k * S, E)     # (G, k·S, E)
    pos = jnp.cumsum(ohf, axis=1) - ohf                     # slots before me
    keep = (pos < capacity) & (ohf > 0)
    slot = jnp.where(keep, pos, 0).astype(jnp.int32)
    disp_f = keep.astype(jnp.float32)[..., None] * jax.nn.one_hot(
        slot, capacity, dtype=jnp.float32) * ohf[..., None]  # (G,kS,E,C)
    disp = disp_f.reshape(G, k, S, E, capacity).transpose(0, 2, 1, 3, 4)
    dispatch = jnp.sum(disp, axis=2)                        # (G,S,E,C)
    w = topv.transpose(0, 2, 1).reshape(G, k, S)            # (G,k,S)
    combine = jnp.sum(disp * w[..., None, None].transpose(0, 2, 1, 3, 4),
                      axis=2)                               # (G,S,E,C)

    # Switch aux loss: E · Σ_e fraction_tokens_e · mean_prob_e
    frac = jnp.mean(jnp.sum(oh, axis=2), axis=(0, 1))       # (E,)
    mprob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mprob) / k
    return dispatch, combine, aux


def moe_ffn(p, x: jax.Array, cfg, constrain=lambda x, l: x,
            group_size: int = 0) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) → (B,S,d), aux_loss.  Groups bound dispatch memory."""
    B, S, d = x.shape
    T = B * S
    g = min(group_size or cfg.moe_group, T)
    while T % g != 0:
        g //= 2
    G = T // g
    xg = x.reshape(G, g, d)
    if cfg.moe_dispatch == "sort":
        return moe_ffn_sorted(p, xg, cfg, constrain, (B, S, d))
    logits = (xg @ p["router"]).astype(jnp.float32)          # (G,g,E)
    C = moe_capacity(cfg, g)
    dispatch, comb, aux = route_topk(logits, cfg.top_k, C)
    ddtype = x.dtype
    # dispatch tokens to experts: (G,g,E,C)×(G,g,d) → (E,G,C,d).
    # NB: activation constraints use *_act logical axes (experts_act →
    # model when EP divides, ffn_act → model for TP-MoE); the token dims
    # stay on the data axes they came from.
    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(ddtype), xg)
    xe = constrain(xe, ("experts_act", "batch", None, None))
    h = jnp.einsum("egcd,edf->egcf", xe, p["w1"])
    h3 = jnp.einsum("egcd,edf->egcf", xe, p["w3"])
    h = jax.nn.silu(h) * h3
    h = constrain(h, ("experts_act", "batch", None, "ffn_act"))
    ye = jnp.einsum("egcf,efd->egcd", h, p["w2"])
    ye = constrain(ye, ("experts_act", "batch", None, None))
    y = jnp.einsum("egcd,gsec->gsd", ye, comb.astype(ddtype))
    return y.reshape(B, S, d), aux


def moe_ffn_sorted(p, xg: jax.Array, cfg, constrain, out_shape
                   ) -> Tuple[jax.Array, jax.Array]:
    """Sort-based dispatch (§Perf hillclimb, beyond-paper optimization).

    The GShard one-hot dispatch costs 2·E·C·d FLOPs *per token* (the one-hot
    einsums), which for qwen3 (E=128, C≈40) is ~10× the active expert
    compute.  Sorting the (token, choice) slots by expert id replaces both
    one-hot einsums with O(T·k·d) gathers/scatters:

      1. top-k route → (G, g·k) expert ids + weights
      2. stable argsort by expert id within each group (G-parallel)
      3. position-in-expert via segment arithmetic; drop beyond capacity
      4. batched scatter  → xe (G, E, C, d)   [E constrained → model = a2a]
      5. expert GEMMs     → ye (G, E, C, f→d)
      6. gather + inverse permutation + top-k-weighted sum back to tokens

    Same capacity/dropping semantics as the one-hot path (tested equal).
    """
    G, g, d = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, g)
    logits = (xg @ p["router"]).astype(jnp.float32)           # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                      # (G,g,k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # flatten slots in CHOICE-MAJOR order (choice 0 of all tokens first) so
    # capacity dropping prefers primary routes — same priority as route_topk
    flat_e = topi.transpose(0, 2, 1).reshape(G, k * g)        # (G, k·g)
    flat_w = topv.transpose(0, 2, 1).reshape(G, k * g)
    flat_tok = jnp.broadcast_to(jnp.arange(g), (G, k, g)).reshape(G, k * g)

    order = jnp.argsort(flat_e, axis=1, stable=True)          # (G, k·g)
    se = jnp.take_along_axis(flat_e, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    stok = jnp.take_along_axis(flat_tok, order, 1)

    # position within each expert segment of the sorted slot list
    idx = jnp.arange(k * g)
    new_seg = jnp.concatenate(
        [jnp.ones((G, 1), bool), se[:, 1:] != se[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(jnp.where(new_seg, idx, 0), axis=1)
    pos = idx - seg_start                                     # (G, k·g)
    keep = pos < C
    posc = jnp.where(keep, pos, 0)
    sec = jnp.where(keep, se, 0)

    # 4. scatter tokens into expert slots (batched over G).  The scatter
    # itself must stay in a (G:data, E:LOCAL) layout — scattering onto a
    # model-sharded E dim makes GSPMD replicate the whole tensor
    # ("involuntary full rematerialization").  The E-axis constraint is
    # applied AFTER the scatter: one clean all-to-all into the GEMM layout.
    gath = jnp.take_along_axis(xg, stok[..., None], axis=1)   # (G, k·g, d)
    gath = jnp.where(keep[..., None], gath, 0)
    xe = jnp.zeros((G, E, C, d), xg.dtype)
    gi = jnp.broadcast_to(jnp.arange(G)[:, None], (G, k * g))
    xe = xe.at[gi, sec, posc].add(gath)
    xe = constrain(xe, ("batch", None, None, None))           # scatter local
    xe = constrain(xe, ("batch", "experts_act", None, None))  # a2a to EP

    # 5. expert GEMMs
    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    h3 = jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    h = jax.nn.silu(h) * h3
    h = constrain(h, ("batch", "experts_act", None, "ffn_act"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    ye = constrain(ye, ("batch", "experts_act", None, None))
    # back to the gather-local layout (reverse all-to-all) before indexing
    ye = constrain(ye, ("batch", None, None, None))

    # 6. gather back, unsort, weighted sum over the k choices
    y_slots = ye[gi, sec, posc] * (sw * keep).astype(ye.dtype)[..., None]
    inv = jnp.argsort(order, axis=1)
    y_unsorted = jnp.take_along_axis(y_slots, inv[..., None], axis=1)
    y = y_unsorted.reshape(G, k, g, d).sum(axis=1)

    oh = jax.nn.one_hot(topi, E, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(oh, axis=2), axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1))) / k
    return y.reshape(out_shape), aux

"""Fault-tolerant, elastic checkpointing (DESIGN.md §3.3).

Format: one directory per step, containing

    manifest.json   — tree structure, per-leaf {shape, dtype, chunks:
                      [{axis0 start/stop, file, crc32}]}, mesh shape, data
                      cursor, PRNG key, "complete" marker written LAST
    <leaf>.<i>.npy  — global-slice chunks (axis-0 partitioned)

Chunks are keyed by **global slice indices**, not device ids, so a restore
may target a *different* mesh (elastic up/down-scaling): the loader
reassembles the global array and ``device_put``s it with the new sharding.
On a real multi-host fleet each host writes the chunks it owns; the format
is host-count-independent by construction.

Durability: writes go to ``<dir>.tmp`` then ``os.rename`` (atomic on POSIX);
``CheckpointManager`` keeps the last *k* steps and can write asynchronously
(snapshot to host memory synchronously, disk I/O on a worker thread — the
training loop never blocks on disk).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"

# numpy can't construct ml_dtypes dtypes from strings ("bfloat16"); store
# such arrays as raw uint views and record the logical dtype in the manifest
try:
    import ml_dtypes
    _EXT_DTYPES = {"bfloat16": ml_dtypes.bfloat16,
                   "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
                   "float8_e5m2": ml_dtypes.float8_e5m2}
except ImportError:  # pragma: no cover
    _EXT_DTYPES = {}
_RAW_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _RAW_VIEW:
        return arr.view(_RAW_VIEW[name]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name])
    return arr.astype(dtype_name)


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(_path_str(p) for p in path) or "leaf"
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(tree: PyTree, directory: str | Path, step: int, *,
                    meta: Optional[Dict] = None, chunks: int = 4) -> Path:
    """Synchronous atomic save. Returns the final step directory."""
    directory = Path(directory)
    final = directory / f"step_{step:010d}"
    tmp = directory / f".tmp_step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest: Dict[str, Any] = {"step": step, "meta": meta or {},
                                "leaves": {}, "format": "repro-ckpt-v1"}
    for name, leaf in _flatten_with_paths(tree):
        arr, dtype_name = _encode(np.asarray(leaf))
        safe = name.replace(_SEP, "__")
        n0 = max(arr.shape[0], 1) if arr.ndim else 1
        k = min(chunks, n0) if arr.ndim else 1
        bounds = np.linspace(0, n0, k + 1, dtype=np.int64)
        chunk_recs = []
        for i in range(k):
            lo, hi = int(bounds[i]), int(bounds[i + 1])
            part = arr[lo:hi] if arr.ndim else arr
            fn = f"{safe}.{i}.npy"
            with open(tmp / fn, "wb") as f:
                np.save(f, part)
            crc = zlib.crc32((tmp / fn).read_bytes())
            chunk_recs.append({"start": lo, "stop": hi, "file": fn,
                               "crc32": crc})
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": dtype_name,
            "chunks": chunk_recs,
        }
    manifest["complete"] = True
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(tree_like: PyTree, directory: str | Path, step: int, *,
                    shardings: Optional[PyTree] = None,
                    verify_crc: bool = True) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``tree_like`` (SDS or arrays); optional
    ``shardings`` pytree re-distributes onto ANY mesh (elastic restore)."""
    d = Path(directory) / f"step_{step:010d}"
    manifest = json.loads((d / "manifest.json").read_text())
    assert manifest.get("complete"), f"incomplete checkpoint {d}"
    leaves = dict(_flatten_with_paths(tree_like))
    shard_leaves = dict(_flatten_with_paths(shardings)) if shardings else {}
    out: Dict[str, Any] = {}
    for name, rec in manifest["leaves"].items():
        parts = []
        for c in rec["chunks"]:
            raw = (d / c["file"]).read_bytes()
            if verify_crc:
                crc = zlib.crc32(raw)
                if crc != c["crc32"]:
                    raise IOError(f"CRC mismatch in {d / c['file']}")
            import io
            parts.append(np.load(io.BytesIO(raw)))
        arr = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        arr = _decode(arr.reshape(rec["shape"]), rec["dtype"])
        sh = shard_leaves.get(name)
        out[name] = jax.device_put(arr, sh) if sh is not None else \
            jax.numpy.asarray(arr)
    # rebuild the pytree in original structure
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    vals = []
    for path, _ in flat:
        name = _SEP.join(_path_str(p) for p in path) or "leaf"
        if name not in out:
            raise KeyError(f"checkpoint missing leaf {name}")
        vals.append(out[name])
    return jax.tree_util.tree_unflatten(treedef, vals), manifest["meta"]


def read_meta(directory: str | Path, step: int) -> Dict:
    """The ``meta`` dict of one complete checkpoint, without loading any
    array data (cheap spec/cursor peeking before a full restore)."""
    d = Path(directory) / f"step_{step:010d}"
    manifest = json.loads((d / "manifest.json").read_text())
    assert manifest.get("complete"), f"incomplete checkpoint {d}"
    return manifest["meta"]


def latest_step(directory: str | Path) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            try:
                m = json.loads((p / "manifest.json").read_text())
                if m.get("complete"):
                    steps.append(int(p.name.split("_")[1]))
            except Exception:
                continue
    return max(steps) if steps else None


class CheckpointManager:
    """Async keep-last-k manager for the host training loop."""

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_write: bool = True, chunks: int = 4):
        self.directory = Path(directory)
        self.keep = keep
        self.async_write = async_write
        self.chunks = chunks
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, tree: PyTree, step: int, meta: Optional[Dict] = None
             ) -> None:
        self.wait()  # one in-flight save at a time
        # snapshot to host memory synchronously (device buffers may be
        # donated/overwritten by the next step)
        snap = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(snap, self.directory, step, meta=meta,
                                chunks=self.chunks)
                self._prune()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error:
                err, self._error = self._error, None
                raise err

    def restore_latest(self, tree_like: PyTree,
                       shardings: Optional[PyTree] = None):
        step = latest_step(self.directory)
        if step is None:
            return None
        tree, meta = load_checkpoint(tree_like, self.directory, step,
                                     shardings=shardings)
        return step, tree, meta

    def _prune(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if p.name.startswith("step_"))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.directory / f"step_{s:010d}",
                          ignore_errors=True)

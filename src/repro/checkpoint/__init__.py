from .manager import (CheckpointManager, save_checkpoint, load_checkpoint,
                      latest_step, read_meta)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "latest_step", "read_meta"]

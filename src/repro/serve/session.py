"""Checkpointable adaptive-sampling sessions.

An :class:`AdaptiveSession` is one running query against the epoch engine,
driven one epoch at a time from the host (``core/substrate.make_stepper``).
Its full resumable state is the per-worker-stacked
:class:`~repro.core.epoch.EpochState` pytree — epoch index, τ, accumulated
frame totals (shards for SHARED_FRAME), pending delta frames, PRNG carry,
stop verdict — plus the frozen :class:`SessionSpec` (strategy / W / F /
substrate / seed / instance name).

The proof obligation of the serving layer: **save → restore → run ≡ run**,
bit-identically, for every strategy.  This is trivial for INDEXED_FRAME
(frames are pure functions of their index) and holds for LOCAL/SHARED
because frame snapshots are *values*, not memory locations — a checkpoint
written at an epoch boundary captures the entire cross-worker contract (the
consistent total plus each worker's not-yet-reduced pending delta), so the
resumed trajectory replays the identical sequence of collectives.

Checkpoints go through :mod:`repro.checkpoint.manager` (global-slice
chunked, CRC'd, atomic-rename) with the spec in the manifest ``meta`` —
``AdaptiveSession.restore(dir)`` needs nothing but the directory.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import (latest_step, load_checkpoint, read_meta,
                                  save_checkpoint)
from ..core.adaptive import AdaptiveResult, result_from_state
from ..core.epoch import EpochConfig
from ..core.frames import FrameStrategy
from ..core.instances import BuiltInstance, get_instance
from ..core.substrate import EpochStepper, make_stepper

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """Frozen description of one query — everything needed to (re)build its
    engine program.  ``instance`` must be a registered workload name so a
    restore can rebuild the sampler from the manifest alone.

    ``logical_world`` is the worker count the sampling streams were *keyed*
    for; it differs from ``world`` only after an elastic re-shard
    (``world`` physical workers each fold ``logical_world/world`` logical
    streams — see :mod:`repro.serve.elastic`).  0 means "same as world".

    ``placement`` pins a ``shard_map`` session to specific device ids — the
    submesh the placement pool leased it (:mod:`repro.serve.placement`).
    ``None`` keeps the historical leading-devices mesh.  Recorded in the
    checkpoint manifest so a resume can re-lease equivalent devices.
    """

    instance: str
    strategy: str = "local"
    world: int = 1
    seed: int = 0
    substrate: Optional[str] = None
    frame_shards: int = 0
    logical_world: int = 0
    placement: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        FrameStrategy(self.strategy)  # validate early
        lw = self.logical_world or self.world
        if lw % self.world != 0:
            raise ValueError(
                f"world={self.world} must divide logical_world={lw}")
        if lw != self.world and \
                FrameStrategy(self.strategy) != FrameStrategy.SHARED_FRAME:
            raise ValueError("folded execution (logical_world != world) is "
                             "an elastic SHARED_FRAME feature")
        if self.placement is not None:
            object.__setattr__(self, "placement", tuple(self.placement))
            if self.substrate != "shard_map":
                raise ValueError("placement pins devices and is only "
                                 "meaningful for substrate='shard_map' "
                                 f"(got {self.substrate!r})")
            if len(self.placement) != self.world:
                raise ValueError(
                    f"placement names {len(self.placement)} device(s) for "
                    f"world={self.world}")

    @property
    def fold(self) -> Optional[int]:
        lw = self.logical_world or self.world
        return None if lw == self.world else lw // self.world

    @property
    def frame_strategy(self) -> FrameStrategy:
        return FrameStrategy(self.strategy)

    def stepper_key(self) -> tuple:
        """Cache key for compiled steppers: everything that changes the
        traced program *or the devices it is bound to*.  The seed is
        deliberately absent — it is a traced scalar of the step function, so
        differently-seeded queries of the same shape share one compilation.
        The placement (mesh device ids) and worker-axis name are present:
        two same-shape sessions on disjoint submeshes must NOT share a
        compiled stepper, or one of them would silently run on the other's
        devices."""
        from ..core.substrate import WORKER_AXIS
        return (self.instance, self.strategy, self.world, self.frame_shards,
                self.substrate, self.logical_world, self.placement,
                WORKER_AXIS)

    def as_meta(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_meta(cls, meta: Dict[str, Any]) -> "SessionSpec":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in meta.items() if k in fields})

    @classmethod
    def parse(cls, spec: str) -> "SessionSpec":
        """Parse the CLI grammar ``instance:strategy:world[:seed]`` (the one
        parser both ``launch.serve --pool`` and ``benchmarks.bench_serve``
        use)."""
        parts = spec.split(":")
        if not 2 <= len(parts) <= 4:
            raise ValueError(f"query spec {spec!r} is not "
                             f"instance:strategy:world[:seed]")
        return cls(instance=parts[0], strategy=parts[1],
                   world=int(parts[2]) if len(parts) > 2 else 1,
                   seed=int(parts[3]) if len(parts) > 3 else 0)


class StepperCache:
    """Shared (built instance, compiled stepper) per session shape.

    One scheduler owns one cache; all queries with the same
    :meth:`SessionSpec.stepper_key` reuse the same jitted single-epoch step,
    so admitting a query of an already-seen shape costs no compilation.
    """

    def __init__(self):
        self._cache: Dict[tuple, Tuple[BuiltInstance, EpochStepper]] = {}

    def get(self, spec: SessionSpec) -> Tuple[BuiltInstance, EpochStepper]:
        key = spec.stepper_key()
        if key not in self._cache:
            self._cache[key] = _build(spec)
        return self._cache[key]

    def __len__(self) -> int:
        return len(self._cache)


def _build(spec: SessionSpec) -> Tuple[BuiltInstance, EpochStepper]:
    inst = get_instance(spec.instance)
    lw = spec.logical_world or spec.world
    # build() pads SHARED frames for the LOGICAL world; every W' | lw then
    # divides the padded length, so any elastic width shards evenly.
    built = inst.build(world=lw, strategy=spec.frame_strategy)
    cfg = EpochConfig(strategy=spec.frame_strategy,
                      rounds_per_epoch=built.rounds_per_epoch,
                      max_epochs=built.max_epochs)
    k = spec.fold
    init_carry = built.init_carry
    if k is not None and init_carry is not None:
        init_carry = jax.tree.map(
            lambda x: jnp.stack([jnp.asarray(x)] * k), init_carry)
    mesh = None
    if spec.placement is not None:
        from ..core.substrate import worker_mesh
        from .placement import lease_devices
        mesh = worker_mesh(spec.world, devices=lease_devices(spec.placement))
    stepper = make_stepper(built.sample_fn, built.check_fn, built.template,
                           init_carry, spec.world, cfg,
                           substrate=spec.substrate,
                           frame_shards=spec.frame_shards, fold=k, mesh=mesh)
    return built, stepper


def _state_to_tree(state) -> PyTree:
    """Checkpoint form: typed PRNG keys become raw uint32 key data."""
    return state._replace(key=jax.random.key_data(state.key))


def _tree_to_state(tree):
    return tree._replace(key=jax.random.wrap_key_data(tree.key))


class AdaptiveSession:
    """One query: spec + engine state + the stepper that advances it.

    Lifecycle::

        s = AdaptiveSession.create(SessionSpec("kadabra", "shared", world=4))
        s.start()
        while not s.done:
            s.step()                  # one epoch (the scheduler's unit)
        estimate, result = s.result()

        s.save(ckpt_dir)              # any epoch boundary
        r = AdaptiveSession.restore(ckpt_dir)
        # r continues bit-identically to an uninterrupted s
    """

    def __init__(self, spec: SessionSpec, built: BuiltInstance,
                 stepper: EpochStepper):
        self.spec = spec
        self.built = built
        self.stepper = stepper
        self.state = None
        self.wall_s = 0.0             # host-measured time spent stepping

    @classmethod
    def create(cls, spec: SessionSpec,
               cache: Optional[StepperCache] = None) -> "AdaptiveSession":
        built, stepper = cache.get(spec) if cache is not None \
            else _build(spec)
        return cls(spec, built, stepper)

    def rebind_placement(self, placement: "Tuple[int, ...] | None",
                         cache: Optional[StepperCache] = None
                         ) -> "AdaptiveSession":
        """Re-bind this session to a different leased submesh (same shape).

        The inter-epoch state is a value pytree, so *which* devices execute
        the next epoch cannot change the trajectory — rebinding swaps the
        stepper (new mesh, possibly a fresh compile via the cache) and keeps
        the state; the next ``step()`` transfers it to the new devices.
        Used on resume/admission when the original devices are taken or
        gone and the pool leased equivalent ones.
        """
        placement = None if placement is None else tuple(placement)
        if placement == self.spec.placement:
            return self
        self.spec = dataclasses.replace(self.spec, placement=placement)
        self.built, self.stepper = cache.get(self.spec) \
            if cache is not None else _build(self.spec)
        return self

    # ------------------------------------------------------------- running
    def start(self) -> "AdaptiveSession":
        t0 = time.perf_counter()
        self.state = self.stepper.init(self.spec.seed)
        self.wall_s += time.perf_counter() - t0
        return self

    @property
    def started(self) -> bool:
        return self.state is not None

    @property
    def done(self) -> bool:
        return self.started and not self.stepper.active(self.state)

    @property
    def epoch(self) -> int:
        assert self.started
        return int(np.asarray(self.state.epoch).reshape(-1)[0])

    @property
    def tau(self) -> int:
        """Samples in the *checked* consistent state (the paper's τ)."""
        assert self.started
        return int(np.asarray(self.state.total.num).reshape(-1)[0])

    def step(self) -> bool:
        """Advance one epoch; returns ``done``.  No-op once stopped."""
        assert self.started, "call start() (or restore) first"
        if self.done:
            return True
        t0 = time.perf_counter()
        self.state = self.stepper.step(self.state, self.spec.seed)
        self.wall_s += time.perf_counter() - t0
        return self.done

    def run(self) -> "AdaptiveSession":
        while not self.done:
            self.step()
        return self

    def result(self) -> Tuple[np.ndarray, AdaptiveResult]:
        """(estimate, AdaptiveResult) from the current consistent state."""
        assert self.started
        res = result_from_state(self.state, strategy=self.spec.frame_strategy,
                                world=self.spec.world,
                                frame_shards=self.spec.frame_shards)
        est = self.built.estimate(self.built.trim(res.data),
                                  float(max(res.num, 1)))
        return est, res

    # -------------------------------------------------------- checkpointing
    def state_template(self) -> PyTree:
        """Shape/dtype skeleton of the checkpoint tree (no FLOPs)."""
        sds = jax.eval_shape(self.stepper.init_fn, self.spec.seed)
        return _state_to_tree_sds(sds)

    def save(self, directory: "str | Path") -> Path:
        """Atomic checkpoint at the current epoch boundary."""
        assert self.started, "nothing to save before start()"
        return save_checkpoint(
            _state_to_tree(self.state), directory, step=self.epoch,
            meta={"spec": self.spec.as_meta(), "kind": "adaptive-session",
                  "tau": self.tau, "wall_s": self.wall_s})

    @classmethod
    def restore(cls, directory: "str | Path", step: Optional[int] = None,
                cache: Optional[StepperCache] = None,
                placement: Any = "keep") -> "AdaptiveSession":
        """Rebuild from a checkpoint directory.  ``placement`` overrides the
        manifest's recorded device ids (pass ``None`` to drop the pin, a
        tuple to re-lease onto different devices) — the state layout is
        placement-independent, so the override is always sound; the default
        ``"keep"`` restores onto the recorded submesh."""
        directory = Path(directory)
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no complete checkpoint in "
                                        f"{directory}")
        meta = read_meta(directory, step)
        spec = SessionSpec.from_meta(meta["spec"])
        if not (isinstance(placement, str) and placement == "keep"):
            spec = dataclasses.replace(
                spec, placement=None if placement is None
                else tuple(placement))
        session = cls.create(spec, cache=cache)
        tree, _meta = load_checkpoint(session.state_template(), directory,
                                      step)
        session.state = _tree_to_state(tree)
        # pre-preemption stepping time carries over so latency accounting
        # (and us_per_call > 0 in BENCH_serve rows) survives a resume.
        session.wall_s = float(meta.get("wall_s", 0.0))
        return session


def _state_to_tree_sds(sds):
    """eval_shape analog of :func:`_state_to_tree` (typed key SDS → raw)."""
    key_sds = jax.eval_shape(jax.random.key_data, sds.key)
    return sds._replace(key=key_sds)

"""Epoch-granular continuous-batching scheduler for adaptive queries.

The paper's loop only synchronizes at epoch boundaries, so an epoch is the
natural scheduling quantum.  :meth:`EpochScheduler.tick` is three stages:

1. **Pressure** (:mod:`repro.serve.placement`, optional): when the queue's
   head cannot be placed, shrink the widest in-flight SHARED_FRAME session
   W → W/2 through :func:`repro.serve.elastic.reshard_session` — the
   paper's Θ(n) ↔ Θ(n/W) memory/width trade-off driven by load instead of
   by hand (the resized session's (τ, estimate) trajectory is bit-identical
   to never having been resized).  When the queue is drained, re-grow
   shrunk sessions toward their logical width.
2. **Admission**: pop queued queries into free slots, bounded by
   ``max_in_flight`` and — when a :class:`~repro.serve.placement.DevicePool`
   is attached — by a **disjoint submesh lease** per query
   (:exc:`PlacementWait` keeps the query queued; the pool accounts in
   worker slots, which are physical devices for ``shard_map`` sessions).
3. **Epoch step + retirement**: advance every in-flight session one epoch
   on its own leased mesh (one batched device step per session shape —
   compiled once via the shared :class:`~repro.serve.session.StepperCache`,
   keyed on shape *and* mesh device ids), retire the sessions whose
   stopping condition fired, and release their leases.

A long-running query therefore never blocks a short one — there is no
run-to-completion head-of-line, only admission policy.

Per-query accounting: submitted/admitted/retired tick, epochs run, final τ,
host wall time, peak ``devices_leased`` and ``placement_wait_ticks`` — the
raw rows of the ``BENCH_serve.json`` throughput/latency artifact
(:mod:`benchmarks.bench_serve`).

Preemption safety: with ``checkpoint_dir`` set, every in-flight session is
checkpointed every ``checkpoint_every`` ticks (epoch boundaries — the only
points where a session state exists at all), the not-yet-admitted queue is
persisted as ``queue.json`` on every submit/tick, and
:meth:`EpochScheduler.resume` rebuilds a scheduler from whatever the
directory holds — restored sessions continue bit-identically (their
recorded placement is re-leased through the pool: the same device ids when
free, an equivalent submesh otherwise), queued queries are resubmitted
fresh.
"""

from __future__ import annotations

import dataclasses
import json
import os
from collections import deque
from pathlib import Path
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .elastic import reshard_session
from .placement import DevicePool, Lease, PlacementWait, PressurePolicy
from .session import AdaptiveSession, SessionSpec, StepperCache

_QUEUE_FILE = "queue.json"


@dataclasses.dataclass(frozen=True)
class _Restore:
    """A checkpointed session awaiting admission: restored lazily so its
    placement can be re-leased through the pool *before* the stepper is
    built (the recorded devices may be taken or gone)."""

    path: Path
    spec: SessionSpec


@dataclasses.dataclass
class QueryResult:
    """Final accounting record of one retired query."""

    qid: str
    spec: SessionSpec
    estimate: np.ndarray
    tau: int
    epochs: int
    stopped: bool                 # False only on the max_epochs safety net
    submitted_tick: int
    admitted_tick: int
    retired_tick: int
    wall_s: float                 # host time spent stepping this query
    devices_leased: int = 0      # peak lease width (0: scheduler had no pool)
    placement_wait_ticks: int = 0  # ticks queued *because the pool was full*

    @property
    def wait_ticks(self) -> int:
        """Ticks spent queued before admission (the latency cost of the
        admission policy, in scheduling quanta)."""
        return self.admitted_tick - self.submitted_tick


@dataclasses.dataclass
class TickEvents:
    tick: int
    admitted: List[str]
    retired: List[str]
    # (qid, old_world, new_world) pressure-driven reshards this tick
    resharded: List[Tuple[str, int, int]] = \
        dataclasses.field(default_factory=list)


class EpochScheduler:
    """Continuous batching over a pool of heterogeneous adaptive queries.

    ``max_in_flight`` bounds concurrently-stepped sessions (device memory is
    dominated by the in-flight frame totals: Θ(n) per LOCAL query, Θ(n/F)
    per SHARED query per worker — the admission policy is the serving-side
    face of the paper's memory trade-off).  ``pool`` adds the placement
    dimension: admission additionally requires a disjoint submesh lease of
    ``spec.world`` slots, and ``pressure`` (requires ``pool``) lets the
    scheduler resize SHARED_FRAME sessions to relieve queue pressure.
    """

    def __init__(self, *, max_in_flight: int = 4,
                 substrate: Optional[str] = None,
                 pool: Optional[DevicePool] = None,
                 pressure: Optional[PressurePolicy] = None,
                 checkpoint_dir: "str | Path | None" = None,
                 checkpoint_every: int = 0):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        if pressure is not None and pool is None:
            raise ValueError("a pressure policy needs a device pool")
        self.max_in_flight = max_in_flight
        self.substrate = substrate
        self.pool = pool
        self.pressure = pressure
        self.checkpoint_dir = Path(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_every = checkpoint_every
        self.cache = StepperCache()
        self._queue: Deque[Tuple[str,
                                 "SessionSpec | AdaptiveSession | _Restore"]]
        self._queue = deque()
        self._active: Dict[str, AdaptiveSession] = {}
        self._leases: Dict[str, Lease] = {}
        self._admitted_tick: Dict[str, int] = {}
        self._submitted_tick: Dict[str, int] = {}
        self._placement_wait: Dict[str, int] = {}
        self._devices_peak: Dict[str, int] = {}
        self.results: Dict[str, QueryResult] = {}
        # checkpointed queries resume() could not re-enqueue (e.g. recorded
        # world wider than the pool) — skipped loudly, never silently
        self.unresumed: List[str] = []
        self.tick_count = 0
        self._n_submitted = 0

    # ------------------------------------------------------------ admission
    @staticmethod
    def _spec_of(item) -> SessionSpec:
        return item.spec if isinstance(item, (AdaptiveSession, _Restore)) \
            else item

    def submit(self, spec: "SessionSpec | AdaptiveSession",
               qid: Optional[str] = None) -> str:
        """Enqueue a query (a spec, or an already-restored session)."""
        inner = self._spec_of(spec)
        if qid is None:
            # skip over ids already taken (e.g. restored from a checkpoint
            # directory whose numbering this counter has not seen)
            while True:
                qid = f"q{self._n_submitted:03d}-{inner.instance}"
                self._n_submitted += 1
                if qid not in self._submitted_tick:
                    break
        elif qid in self._submitted_tick:
            raise ValueError(f"duplicate query id {qid!r}")
        if self.pool is not None and inner.world > self.pool.capacity:
            raise ValueError(
                f"query {qid!r} needs {inner.world} worker slot(s) but the "
                f"pool holds only {self.pool.capacity} — it could never be "
                f"admitted")
        if self.substrate is not None and isinstance(spec, SessionSpec) \
                and spec.substrate is None:
            spec = dataclasses.replace(spec, substrate=self.substrate)
        self._submitted_tick[qid] = self.tick_count
        self._placement_wait[qid] = 0
        self._queue.append((qid, spec))
        self._persist_queue()
        return qid

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        return len(self._active)

    @property
    def idle(self) -> bool:
        return not self._queue and not self._active

    def _note_lease(self, qid: str, lease: Optional[Lease]) -> None:
        if lease is None:
            return
        self._leases[qid] = lease
        self._devices_peak[qid] = max(self._devices_peak.get(qid, 0),
                                      lease.width)

    def _materialize(self, item, lease: Optional[Lease]) -> AdaptiveSession:
        """Turn a queue entry into a started session bound to its lease."""
        ids = None if lease is None else lease.ids
        if isinstance(item, _Restore):
            spec = item.spec
            if spec.substrate == "shard_map" and ids is not None \
                    and ids != spec.placement:
                return AdaptiveSession.restore(item.path, cache=self.cache,
                                               placement=ids)
            return AdaptiveSession.restore(item.path, cache=self.cache)
        if isinstance(item, AdaptiveSession):
            if item.spec.substrate == "shard_map" and ids is not None \
                    and ids != item.spec.placement:
                item.rebind_placement(ids, cache=self.cache)
            return item               # restored mid-run; already started
        spec = item
        if spec.substrate == "shard_map" and ids is not None:
            spec = dataclasses.replace(spec, placement=ids)
        return AdaptiveSession.create(spec, cache=self.cache).start()

    def _admit(self) -> Tuple[List[str], bool]:
        """Admission stage: lease a submesh per queued query (FIFO) until
        the pool or the in-flight budget blocks.  Returns the admitted ids
        and whether admission stopped on placement (vs max_in_flight)."""
        admitted: List[str] = []
        blocked_on_placement = False
        while self._queue and len(self._active) < self.max_in_flight:
            qid, item = self._queue[0]
            spec = self._spec_of(item)
            lease = None
            if self.pool is not None:
                try:
                    lease = self.pool.lease(spec.world,
                                            prefer=spec.placement)
                except PlacementWait:
                    blocked_on_placement = True
                    break            # FIFO: the head waits for capacity
            self._queue.popleft()
            self._note_lease(qid, lease)
            self._active[qid] = self._materialize(item, lease)
            self._admitted_tick[qid] = self.tick_count
            admitted.append(qid)
        return admitted, blocked_on_placement

    # ------------------------------------------------------------- pressure
    def _shrink_candidates(self) -> List[str]:
        assert self.pressure is not None
        floor = max(1, self.pressure.min_world)
        cands = [
            qid for qid, s in self._active.items()
            if s.spec.strategy == "shared" and not s.done
            and s.spec.world % 2 == 0 and s.spec.world // 2 >= floor]
        # widest first (frees the most slots); qid tiebreak for determinism
        return sorted(cands,
                      key=lambda q: (-self._active[q].spec.world, q))

    def _resize(self, qid: str, new_world: int) -> Tuple[int, int]:
        """Reshard one in-flight session to ``new_world`` and resize its
        lease to match.  Returns (old_world, new_world)."""
        session = self._active[qid]
        old_world = session.spec.world
        lease = self._leases.get(qid)
        placement = None
        if lease is not None:
            lease = self.pool.resize(lease, new_world)
            self._note_lease(qid, lease)
            if session.spec.substrate == "shard_map":
                placement = lease.ids
        self._active[qid] = reshard_session(
            session, new_world, cache=self.cache, placement=placement,
            substrate=None if placement is not None
            else session.spec.substrate)
        return old_world, new_world

    def _apply_pressure(self) -> List[Tuple[str, int, int]]:
        """Pressure stage: shrink under queue pressure, re-grow on drain."""
        if self.pressure is None or self.pool is None:
            return []
        events: List[Tuple[str, int, int]] = []
        if self._queue and len(self._active) < self.max_in_flight:
            # queued demand exceeds free devices → halve the widest
            # SHARED_FRAME session until the head fits (or nothing shrinks)
            head_spec = self._spec_of(self._queue[0][1])
            while self.pool.free < head_spec.world:
                cands = self._shrink_candidates()
                if not cands:
                    break
                qid = cands[0]
                old, new = self._resize(
                    qid, self._active[qid].spec.world // 2)
                events.append((qid, old, new))
        elif not self._queue and self.pressure.regrow and self.pool.free:
            # drained queue + free devices → give width back (one doubling
            # step per session per tick keeps re-grow gentle)
            for qid in sorted(self._active):
                session = self._active[qid]
                spec = session.spec
                lw = spec.logical_world or spec.world
                target = spec.world * 2
                if spec.strategy != "shared" or session.done \
                        or target > lw or lw % target != 0 \
                        or self.pool.free < target - spec.world:
                    continue
                old, new = self._resize(qid, target)
                events.append((qid, old, new))
        return events

    # ----------------------------------------------------------- the tick
    def tick(self) -> TickEvents:
        """One scheduling quantum: relieve placement pressure → admit (lease
        a submesh per query) → step every in-flight query one epoch on its
        own leased mesh → retire at the epoch boundary (releasing leases)."""
        resharded = self._apply_pressure()
        admitted, blocked_on_placement = self._admit()

        retired: List[str] = []
        for qid, session in list(self._active.items()):
            session.step()
            if session.done:
                retired.append(qid)

        for qid in retired:
            session = self._active.pop(qid)
            lease = self._leases.pop(qid, None)
            if lease is not None:
                self.pool.release(lease)
            est, res = session.result()
            self.results[qid] = QueryResult(
                qid=qid, spec=session.spec, estimate=np.asarray(est),
                tau=res.num, epochs=res.epochs, stopped=res.stopped,
                submitted_tick=self._submitted_tick[qid],
                admitted_tick=self._admitted_tick[qid],
                retired_tick=self.tick_count, wall_s=session.wall_s,
                devices_leased=self._devices_peak.get(qid, 0),
                placement_wait_ticks=self._placement_wait.get(qid, 0))
            if self.checkpoint_dir is not None:
                # final state persists too — a restore after drain sees the
                # query as done instead of re-running it.
                session.save(self.checkpoint_dir / qid)

        if blocked_on_placement:
            # the queue spent this tick waiting on devices, not on the
            # in-flight budget — that is placement latency, and it is what
            # the BENCH_serve `placement_wait_ticks` column measures.
            for qid, _ in self._queue:
                self._placement_wait[qid] += 1

        self.tick_count += 1
        if self.checkpoint_dir is not None:
            self._persist_queue()
            if self.checkpoint_every and \
                    self.tick_count % self.checkpoint_every == 0:
                self.save_all()
        return TickEvents(tick=self.tick_count - 1, admitted=admitted,
                          retired=retired, resharded=resharded)

    def drain(self, max_ticks: int = 100_000) -> List[TickEvents]:
        """Tick until queue and pool are empty (every query retired)."""
        events = []
        while not self.idle:
            if self.tick_count >= max_ticks:
                raise RuntimeError(f"scheduler did not drain in {max_ticks} "
                                   f"ticks ({self.in_flight} in flight)")
            events.append(self.tick())
        return events

    # -------------------------------------------------------- checkpointing
    def _persist_queue(self) -> None:
        """Atomically mirror every unretired query (queued AND in-flight)
        to disk, so a preemption cannot silently drop queries that never
        got a session checkpoint of their own.  On resume, a per-query
        checkpoint subdirectory wins (bit-identical continuation); entries
        with no checkpoint are resubmitted fresh — at-least-once execution,
        never silent loss."""
        if self.checkpoint_dir is None:
            return
        self.checkpoint_dir.mkdir(parents=True, exist_ok=True)
        entries = [{"qid": qid, "spec": self._spec_of(item).as_meta()}
                   for qid, item in self._queue]
        entries += [{"qid": qid, "spec": session.spec.as_meta()}
                    for qid, session in self._active.items()]
        tmp = self.checkpoint_dir / (_QUEUE_FILE + ".tmp")
        tmp.write_text(json.dumps(entries))
        os.rename(tmp, self.checkpoint_dir / _QUEUE_FILE)

    def save_all(self) -> None:
        assert self.checkpoint_dir is not None
        for qid, session in self._active.items():
            session.save(self.checkpoint_dir / qid)
        self._persist_queue()

    @classmethod
    def resume(cls, checkpoint_dir: "str | Path", *,
               max_in_flight: int = 4, substrate: Optional[str] = None,
               pool: Optional[DevicePool] = None,
               pressure: Optional[PressurePolicy] = None,
               checkpoint_every: int = 0) -> "EpochScheduler":
        """Rebuild a scheduler from a checkpoint directory: every per-query
        subdirectory with a complete checkpoint is resubmitted as a pending
        restore — materialized at admission, so its recorded placement is
        first re-leased through ``pool`` (the same device ids when free, an
        equivalent submesh otherwise); done sessions retire on their first
        tick without stepping (``step()`` is a no-op once stopped) — and
        queries persisted in ``queue.json`` that never earned a checkpoint
        of their own are resubmitted fresh under their original ids.

        Entries that can *never* be placed on ``pool`` (recorded world wider
        than the pool's capacity) are left out rather than aborting the
        whole restore: their ids land in ``sched.unresumed``, a warning
        names them, and their checkpoints stay on disk untouched (resume
        them on an adequate pool, or re-shard by hand)."""
        import warnings

        from ..checkpoint.manager import latest_step, read_meta
        sched = cls(max_in_flight=max_in_flight, substrate=substrate,
                    pool=pool, pressure=pressure,
                    checkpoint_dir=checkpoint_dir,
                    checkpoint_every=checkpoint_every)
        root = Path(checkpoint_dir)

        def try_submit(item, qid):
            try:
                sched.submit(item, qid=qid)
            except ValueError as e:
                sched.unresumed.append(qid)
                warnings.warn(f"resume skipped {qid!r}: {e}", stacklevel=3)

        for sub in sorted(p for p in root.iterdir() if p.is_dir()):
            step = latest_step(sub)
            if step is None:
                continue
            spec = SessionSpec.from_meta(read_meta(sub, step)["spec"])
            try_submit(_Restore(path=sub, spec=spec), sub.name)
        queue_file = root / _QUEUE_FILE
        if queue_file.exists():
            for entry in json.loads(queue_file.read_text()):
                if entry["qid"] not in sched._submitted_tick \
                        and entry["qid"] not in sched.unresumed:
                    try_submit(SessionSpec.from_meta(entry["spec"]),
                               entry["qid"])
        return sched

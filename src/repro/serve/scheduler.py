"""Epoch-granular continuous-batching scheduler for adaptive queries.

The paper's loop only synchronizes at epoch boundaries, so an epoch is the
natural scheduling quantum: each scheduler *tick* advances every in-flight
query by exactly one epoch (one batched device step per query shape —
compiled once via the shared :class:`~repro.serve.session.StepperCache`),
retires the queries whose stopping condition fired, and admits queued
queries into the freed slots for the *next* tick.  A long-running query
therefore never blocks a short one — there is no run-to-completion
head-of-line, only the max-in-flight admission policy.

Per-query accounting: submitted/admitted/retired tick, epochs run, final τ,
and host wall time spent stepping — the raw rows of the ``BENCH_serve.json``
throughput/latency artifact (:mod:`benchmarks.bench_serve`).

Preemption safety: with ``checkpoint_dir`` set, every in-flight session is
checkpointed every ``checkpoint_every`` ticks (epoch boundaries — the only
points where a session state exists at all), the not-yet-admitted queue is
persisted as ``queue.json`` on every submit/tick, and
:meth:`EpochScheduler.resume` rebuilds a scheduler from whatever the
directory holds — restored sessions continue bit-identically, queued
queries are resubmitted fresh.
"""

from __future__ import annotations

import dataclasses
import json
import os
from collections import deque
from pathlib import Path
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .session import AdaptiveSession, SessionSpec, StepperCache

_QUEUE_FILE = "queue.json"


@dataclasses.dataclass
class QueryResult:
    """Final accounting record of one retired query."""

    qid: str
    spec: SessionSpec
    estimate: np.ndarray
    tau: int
    epochs: int
    stopped: bool                 # False only on the max_epochs safety net
    submitted_tick: int
    admitted_tick: int
    retired_tick: int
    wall_s: float                 # host time spent stepping this query

    @property
    def wait_ticks(self) -> int:
        """Ticks spent queued before admission (the latency cost of the
        admission policy, in scheduling quanta)."""
        return self.admitted_tick - self.submitted_tick


@dataclasses.dataclass
class TickEvents:
    tick: int
    admitted: List[str]
    retired: List[str]


class EpochScheduler:
    """Continuous batching over a pool of heterogeneous adaptive queries.

    ``max_in_flight`` bounds concurrently-stepped sessions (device memory is
    dominated by the in-flight frame totals: Θ(n) per LOCAL query, Θ(n/F)
    per SHARED query per worker — the admission policy is the serving-side
    face of the paper's memory trade-off).
    """

    def __init__(self, *, max_in_flight: int = 4,
                 substrate: Optional[str] = None,
                 checkpoint_dir: "str | Path | None" = None,
                 checkpoint_every: int = 0):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        self.max_in_flight = max_in_flight
        self.substrate = substrate
        self.checkpoint_dir = Path(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_every = checkpoint_every
        self.cache = StepperCache()
        self._queue: Deque[Tuple[str, "SessionSpec | AdaptiveSession"]]
        self._queue = deque()
        self._active: Dict[str, AdaptiveSession] = {}
        self._admitted_tick: Dict[str, int] = {}
        self._submitted_tick: Dict[str, int] = {}
        self.results: Dict[str, QueryResult] = {}
        self.tick_count = 0
        self._n_submitted = 0

    # ------------------------------------------------------------ admission
    def submit(self, spec: "SessionSpec | AdaptiveSession",
               qid: Optional[str] = None) -> str:
        """Enqueue a query (a spec, or an already-restored session)."""
        inner = spec.spec if isinstance(spec, AdaptiveSession) else spec
        if qid is None:
            # skip over ids already taken (e.g. restored from a checkpoint
            # directory whose numbering this counter has not seen)
            while True:
                qid = f"q{self._n_submitted:03d}-{inner.instance}"
                self._n_submitted += 1
                if qid not in self._submitted_tick:
                    break
        elif qid in self._submitted_tick:
            raise ValueError(f"duplicate query id {qid!r}")
        if self.substrate is not None and isinstance(spec, SessionSpec) \
                and spec.substrate is None:
            spec = dataclasses.replace(spec, substrate=self.substrate)
        self._submitted_tick[qid] = self.tick_count
        self._queue.append((qid, spec))
        self._persist_queue()
        return qid

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        return len(self._active)

    @property
    def idle(self) -> bool:
        return not self._queue and not self._active

    # ----------------------------------------------------------- the tick
    def tick(self) -> TickEvents:
        """One scheduling quantum: admit → step every in-flight query one
        epoch → retire at the epoch boundary."""
        admitted: List[str] = []
        while self._queue and len(self._active) < self.max_in_flight:
            qid, item = self._queue.popleft()
            if isinstance(item, AdaptiveSession):
                session = item           # restored mid-run; already started
            else:
                session = AdaptiveSession.create(item, cache=self.cache)
                session.start()
            self._active[qid] = session
            self._admitted_tick[qid] = self.tick_count
            admitted.append(qid)

        retired: List[str] = []
        for qid, session in list(self._active.items()):
            session.step()
            if session.done:
                retired.append(qid)

        for qid in retired:
            session = self._active.pop(qid)
            est, res = session.result()
            self.results[qid] = QueryResult(
                qid=qid, spec=session.spec, estimate=np.asarray(est),
                tau=res.num, epochs=res.epochs, stopped=res.stopped,
                submitted_tick=self._submitted_tick[qid],
                admitted_tick=self._admitted_tick[qid],
                retired_tick=self.tick_count, wall_s=session.wall_s)
            if self.checkpoint_dir is not None:
                # final state persists too — a restore after drain sees the
                # query as done instead of re-running it.
                session.save(self.checkpoint_dir / qid)

        self.tick_count += 1
        if self.checkpoint_dir is not None:
            self._persist_queue()
            if self.checkpoint_every and \
                    self.tick_count % self.checkpoint_every == 0:
                self.save_all()
        return TickEvents(tick=self.tick_count - 1, admitted=admitted,
                          retired=retired)

    def drain(self, max_ticks: int = 100_000) -> List[TickEvents]:
        """Tick until queue and pool are empty (every query retired)."""
        events = []
        while not self.idle:
            if self.tick_count >= max_ticks:
                raise RuntimeError(f"scheduler did not drain in {max_ticks} "
                                   f"ticks ({self.in_flight} in flight)")
            events.append(self.tick())
        return events

    # -------------------------------------------------------- checkpointing
    def _persist_queue(self) -> None:
        """Atomically mirror every unretired query (queued AND in-flight)
        to disk, so a preemption cannot silently drop queries that never
        got a session checkpoint of their own.  On resume, a per-query
        checkpoint subdirectory wins (bit-identical continuation); entries
        with no checkpoint are resubmitted fresh — at-least-once execution,
        never silent loss."""
        if self.checkpoint_dir is None:
            return
        self.checkpoint_dir.mkdir(parents=True, exist_ok=True)
        entries = [{"qid": qid,
                    "spec": (item.spec if isinstance(item, AdaptiveSession)
                             else item).as_meta()}
                   for qid, item in self._queue]
        entries += [{"qid": qid, "spec": session.spec.as_meta()}
                    for qid, session in self._active.items()]
        tmp = self.checkpoint_dir / (_QUEUE_FILE + ".tmp")
        tmp.write_text(json.dumps(entries))
        os.rename(tmp, self.checkpoint_dir / _QUEUE_FILE)

    def save_all(self) -> None:
        assert self.checkpoint_dir is not None
        for qid, session in self._active.items():
            session.save(self.checkpoint_dir / qid)
        self._persist_queue()

    @classmethod
    def resume(cls, checkpoint_dir: "str | Path", *,
               max_in_flight: int = 4, substrate: Optional[str] = None,
               checkpoint_every: int = 0) -> "EpochScheduler":
        """Rebuild a scheduler from a checkpoint directory: every per-query
        subdirectory with a complete checkpoint is resubmitted as a restored
        session (done sessions retire on their first tick without stepping —
        ``step()`` is a no-op once stopped), and queries persisted in
        ``queue.json`` that never earned a checkpoint of their own are
        resubmitted fresh under their original ids."""
        sched = cls(max_in_flight=max_in_flight, substrate=substrate,
                    checkpoint_dir=checkpoint_dir,
                    checkpoint_every=checkpoint_every)
        root = Path(checkpoint_dir)
        for sub in sorted(p for p in root.iterdir() if p.is_dir()):
            try:
                session = AdaptiveSession.restore(sub, cache=sched.cache)
            except FileNotFoundError:
                continue
            sched.submit(session, qid=sub.name)
        queue_file = root / _QUEUE_FILE
        if queue_file.exists():
            for entry in json.loads(queue_file.read_text()):
                if entry["qid"] not in sched._submitted_tick:
                    sched.submit(SessionSpec.from_meta(entry["spec"]),
                                 qid=entry["qid"])
        return sched

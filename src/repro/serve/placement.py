"""Placement: a device-topology pool that carves disjoint submeshes.

The paper's almost-no-synchronization property means workers only coordinate
at epoch boundaries, *within* one session — two sessions never coordinate at
all.  A machine's devices can therefore be partitioned into **disjoint
submeshes** that each run an independent session with zero cross-session
synchronization (the same property the MPI follow-up, van der Grinten &
Meyerhenke 2019, exploits across hosts).  This module models that:

* :class:`DeviceTopology` — the machine: device ids grouped into locality
  domains (hosts/processes).  Built from the live JAX runtime
  (:meth:`DeviceTopology.from_host`) or parsed from a CLI spec
  (:meth:`DeviceTopology.parse`, e.g. ``"8"`` or ``"2x4"``).
* :class:`DevicePool` — lease/release bookkeeping over a topology.
  :meth:`DevicePool.lease` carves a width-``n`` submesh whose device ids are
  **pairwise disjoint** from every live lease, preferring whole aligned
  blocks inside a single locality group (so a W=4 lease on an 8-device host
  is ``[0..3]`` and the next one ``[4..7]``); it raises
  :exc:`PlacementWait` when demand exceeds free capacity — the scheduler's
  signal to queue the query rather than contend.
* :class:`PressurePolicy` — when/how the scheduler trades the paper's
  Θ(n) ↔ Θ(n/W) memory/width trade-off *by load*: shrink a SHARED_FRAME
  session W → W/2 when queued demand exceeds free devices, re-grow toward
  its logical width when the queue drains.

The pool accounts in **worker slots**: a session's footprint is its
``world``.  Under ``shard_map`` each slot is a physical device and the
lease's ids become the session's mesh (``lease_devices``); under ``vmap``
the W virtual workers timeshare one device, but the lease still reserves W
slots so admission and pressure behave identically across substrates (and
are testable on a 1-device host with an abstract topology).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class PlacementWait(RuntimeError):
    """Demand exceeds the pool's free capacity *right now* — the caller
    should queue and retry at a later tick, not treat this as fatal."""

    def __init__(self, width: int, free: int):
        super().__init__(f"placement wait: need {width} device(s), "
                         f"{free} free")
        self.width = width
        self.free = free


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """Device ids grouped by locality domain (host/process).

    ``groups`` is a tuple of id-tuples; ids are globally unique.  A lease
    prefers to fit inside one group (cross-group submeshes are the
    multi-host regime — allowed, but only after single-group placement
    fails).
    """

    groups: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        ids = list(itertools.chain.from_iterable(self.groups))
        if not ids:
            raise ValueError("topology has no devices")
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate device ids in topology: {ids}")

    @property
    def ids(self) -> Tuple[int, ...]:
        return tuple(itertools.chain.from_iterable(self.groups))

    @property
    def num_devices(self) -> int:
        return sum(len(g) for g in self.groups)

    @classmethod
    def from_host(cls) -> "DeviceTopology":
        """The live JAX runtime, grouped by process index (one group per
        host in a multi-process run; one group of all local/virtual devices
        otherwise)."""
        import jax
        by_proc: Dict[int, List[int]] = {}
        for d in jax.devices():
            by_proc.setdefault(d.process_index, []).append(d.id)
        return cls(groups=tuple(tuple(sorted(v))
                                for _, v in sorted(by_proc.items())))

    @classmethod
    def parse(cls, spec: str) -> "DeviceTopology":
        """CLI grammar: ``"auto"`` → :meth:`from_host`; ``"N"`` → one group
        of N abstract ids; ``"GxN"`` → G groups of N (e.g. ``"2x4"``)."""
        spec = spec.strip().lower()
        if spec in ("auto", "host"):
            return cls.from_host()
        if "x" in spec:
            g_s, n_s = spec.split("x", 1)
            g, n = int(g_s), int(n_s)
        else:
            g, n = 1, int(spec)
        if g < 1 or n < 1:
            raise ValueError(f"topology spec {spec!r} must be positive")
        return cls(groups=tuple(tuple(range(i * n, (i + 1) * n))
                                for i in range(g)))


@dataclasses.dataclass(frozen=True)
class Lease:
    """A carved submesh: ``width`` device ids, disjoint from every other
    live lease of the pool that issued it."""

    lid: int
    ids: Tuple[int, ...]

    @property
    def width(self) -> int:
        return len(self.ids)


class DevicePool:
    """Lease/release bookkeeping over a :class:`DeviceTopology`.

    Invariants (property-tested in ``tests/test_placement.py``):

    * live leases are pairwise disjoint;
    * ``free + in_use == capacity`` at all times, and lease → release
      round-trips restore ``free`` exactly;
    * no lease is ever carved outside the topology's ids.
    """

    def __init__(self, topology: "DeviceTopology | int | Sequence[int]"):
        if isinstance(topology, int):
            topology = DeviceTopology(groups=(tuple(range(topology)),))
        elif not isinstance(topology, DeviceTopology):
            topology = DeviceTopology(groups=(tuple(topology),))
        self.topology = topology
        self._free: List[int] = list(topology.ids)
        self._leases: Dict[int, Lease] = {}
        self._next_lid = 0

    # ------------------------------------------------------------- queries
    @property
    def capacity(self) -> int:
        return self.topology.num_devices

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - self.free

    @property
    def leases(self) -> Tuple[Lease, ...]:
        return tuple(self._leases.values())

    def free_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(self._free))

    # ------------------------------------------------------------- leasing
    def _take(self, ids: Sequence[int]) -> Lease:
        for i in ids:
            self._free.remove(i)
        lease = Lease(lid=self._next_lid, ids=tuple(ids))
        self._next_lid += 1
        self._leases[lease.lid] = lease
        return lease

    def _carve(self, width: int) -> Optional[List[int]]:
        """Pick ``width`` free ids: aligned block in one group → contiguous
        run in one group → any free ids in one group → span groups."""
        free = set(self._free)
        for group in self.topology.groups:
            # whole aligned blocks first (keeps halves of a host intact)
            for i in range(0, len(group) - width + 1, width):
                block = group[i:i + width]
                if free.issuperset(block):
                    return list(block)
        for group in self.topology.groups:
            for i in range(len(group) - width + 1):
                block = group[i:i + width]
                if free.issuperset(block):
                    return list(block)
        for group in self.topology.groups:
            avail = sorted(free.intersection(group))
            if len(avail) >= width:
                return avail[:width]
        if len(free) >= width:        # cross-group (multi-host) fallback
            return sorted(free)[:width]
        return None

    def lease(self, width: int,
              prefer: Optional[Iterable[int]] = None) -> Lease:
        """Carve a disjoint width-``width`` submesh; raises
        :exc:`PlacementWait` when fewer than ``width`` ids are free.

        ``prefer`` re-leases an exact id set when every id is free (how a
        resumed session gets *equivalent* devices back — same ids if
        available, same width otherwise)."""
        if width < 1:
            raise ValueError(f"lease width must be >= 1, got {width}")
        if width > self.capacity:
            raise ValueError(f"lease width {width} exceeds pool capacity "
                             f"{self.capacity}")
        if prefer is not None:
            ids = tuple(prefer)
            if len(ids) == width and set(ids) <= set(self._free):
                return self._take(ids)
        picked = self._carve(width)
        if picked is None:
            raise PlacementWait(width, self.free)
        return self._take(picked)

    def release(self, lease: Lease) -> None:
        stored = self._leases.pop(lease.lid, None)
        if stored is None:
            raise ValueError(f"lease {lease.lid} is not live in this pool")
        # free the POOL's record of the lease, not the caller's argument — a
        # stale pre-resize Lease object must not double-free resized-away
        # ids (that would hand the same device to two "disjoint" leases).
        self._free.extend(stored.ids)

    def resize(self, lease: Lease, new_width: int) -> Lease:
        """Shrink or grow a live lease in place (same lid namespace).

        Shrinking keeps the lease's **leading** ids and frees the tail —
        exactly the submesh a W → W′ elastic re-shard keeps running on.
        Growing claims additional free ids (contiguous after the lease when
        possible) and raises :exc:`PlacementWait` when the pool cannot
        supply them."""
        if lease.lid not in self._leases:
            raise ValueError(f"lease {lease.lid} is not live in this pool")
        lease = self._leases[lease.lid]   # stale args resolve to live state
        if new_width < 1:
            raise ValueError(f"new_width must be >= 1, got {new_width}")
        if new_width == lease.width:
            return lease
        if new_width < lease.width:
            keep, drop = lease.ids[:new_width], lease.ids[new_width:]
            self._free.extend(drop)
            new = Lease(lid=lease.lid, ids=keep)
            self._leases[lease.lid] = new
            return new
        extra = new_width - lease.width
        free = set(self._free)
        tail = lease.ids[-1]
        contiguous = [i for i in range(tail + 1, tail + 1 + extra)
                      if i in free]
        picked = contiguous if len(contiguous) == extra else \
            sorted(free)[:extra]
        if len(picked) < extra:
            raise PlacementWait(extra, self.free)
        for i in picked:
            self._free.remove(i)
        new = Lease(lid=lease.lid, ids=lease.ids + tuple(picked))
        self._leases[lease.lid] = new
        return new


def lease_devices(ids: Iterable[int]) -> list:
    """The live ``jax.Device`` objects for leased ids, in lease order.

    Raises with the available ids when a leased id is not present on this
    host — the placement was recorded for a differently-provisioned machine
    (e.g. a checkpoint resumed without re-leasing through the pool)."""
    import jax
    by_id = {d.id: d for d in jax.devices()}
    missing = [i for i in ids if i not in by_id]
    if missing:
        raise RuntimeError(
            f"leased device ids {missing} not present on this host "
            f"(available: {sorted(by_id)}) — re-lease through the "
            f"DevicePool instead of reusing a recorded placement verbatim")
    return [by_id[i] for i in ids]


@dataclasses.dataclass(frozen=True)
class PressurePolicy:
    """When the scheduler trades session width for admission throughput.

    *Shrink*: while the queue's head cannot be placed and some in-flight
    SHARED_FRAME session is wider than ``min_world``, halve the widest one
    (W → W/2 keeps W′ dividing the logical width, so the re-shard is always
    legal) — per-worker memory rises Θ(n/W) → Θ(n/W′) but ``W/2`` devices
    free up for the queued query.

    *Regrow*: when the queue is drained and devices sit free, grow shrunk
    sessions back toward their logical width (doubling steps), reclaiming
    the parallelism the shrink gave away.

    Both transformations go through :func:`repro.serve.elastic.
    reshard_session`, so the session's (τ, estimate) trajectory is
    **bit-identical** to never having been resized at all.
    """

    min_world: int = 1
    regrow: bool = True

    @classmethod
    def parse(cls, spec: str) -> "Optional[PressurePolicy]":
        """CLI grammar: ``"none"`` → None; ``"shrink"`` (no regrow);
        ``"shrink-regrow"``; optional ``":min=N"`` suffix."""
        spec = spec.strip().lower()
        if spec in ("", "none", "off"):
            return None
        base, _, opt = spec.partition(":")
        if base not in ("shrink", "shrink-regrow"):
            raise ValueError(f"unknown pressure policy {spec!r} "
                             f"(none | shrink | shrink-regrow[:min=N])")
        min_world = 1
        if opt:
            key, _, val = opt.partition("=")
            if key != "min":
                raise ValueError(f"unknown pressure option {opt!r}")
            min_world = int(val)
        return cls(min_world=min_world, regrow=base == "shrink-regrow")

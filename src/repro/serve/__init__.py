"""Adaptive-sampling serving subsystem.

The paper's "almost no synchronization" property is exactly what a *service*
needs to run many concurrent approximation queries on one device mesh
without head-of-line blocking: queries only interact with the scheduler at
epoch boundaries, where the engine state is a plain value pytree.

Three pieces:

* :mod:`repro.serve.session` — :class:`AdaptiveSession`, a checkpointable,
  resumable handle on one running query (bit-identical resume).
* :mod:`repro.serve.scheduler` — :class:`EpochScheduler`, epoch-granular
  continuous batching over a pool of heterogeneous sessions with a
  max-in-flight admission policy and per-query τ accounting.
* :mod:`repro.serve.elastic` — elastic re-sharding of SHARED_FRAME sessions
  (resume at a different worker width W′ | W, bit-identical (τ, estimate)),
  plus the train-side :func:`elastic_restore` absorbed from
  ``runtime/elastic.py``.
"""

from .elastic import elastic_restore, reshard_session
from .scheduler import EpochScheduler, QueryResult
from .session import AdaptiveSession, SessionSpec, StepperCache

__all__ = [
    "AdaptiveSession", "EpochScheduler", "QueryResult", "SessionSpec",
    "StepperCache", "elastic_restore", "reshard_session",
]

"""Adaptive-sampling serving subsystem.

The paper's "almost no synchronization" property is exactly what a *service*
needs to run many concurrent approximation queries on one device mesh
without head-of-line blocking: queries only interact with the scheduler at
epoch boundaries, where the engine state is a plain value pytree.

Three pieces:

* :mod:`repro.serve.session` — :class:`AdaptiveSession`, a checkpointable,
  resumable handle on one running query (bit-identical resume).
* :mod:`repro.serve.scheduler` — :class:`EpochScheduler`, epoch-granular
  continuous batching over a pool of heterogeneous sessions with a
  max-in-flight admission policy and per-query τ accounting.
* :mod:`repro.serve.elastic` — elastic re-sharding of SHARED_FRAME sessions
  (resume at a different worker width W′ | W, bit-identical (τ, estimate)),
  plus the train-side :func:`elastic_restore` absorbed from
  ``runtime/elastic.py``.
* :mod:`repro.serve.placement` — the device-topology pool: carve pairwise-
  disjoint submeshes with lease/release semantics so concurrent sessions
  run on *different* devices instead of contending for the leading ones,
  and the :class:`PressurePolicy` that resizes SHARED_FRAME sessions under
  queued load.
"""

from .elastic import elastic_restore, reshard_session
from .placement import (DevicePool, DeviceTopology, Lease, PlacementWait,
                        PressurePolicy)
from .scheduler import EpochScheduler, QueryResult
from .session import AdaptiveSession, SessionSpec, StepperCache

__all__ = [
    "AdaptiveSession", "DevicePool", "DeviceTopology", "EpochScheduler",
    "Lease", "PlacementWait", "PressurePolicy", "QueryResult", "SessionSpec",
    "StepperCache", "elastic_restore", "reshard_session",
]

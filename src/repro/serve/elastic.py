"""Elastic re-sharding: resume a session at a different worker width.

The paper's SHARED_FRAME strategy trades memory for bandwidth: each worker
keeps only a 1/F shard of the consistent state (Θ(n/F) instead of Θ(n)).
This module makes that trade-off *dynamic*: a SHARED_FRAME session started
at logical width W can be re-shard-resumed on W′ physical workers for any
W′ | W —

1. the consistent total is **reassembled** from the old per-worker shard
   layout round-robin across the redundant groups (PR 3's grouped-
   reassembly path, :func:`repro.core.adaptive.reassemble_shared`), then
   **re-scattered** into W′ contiguous shards of n/W′ each;
2. the W logical sampling streams (PRNG keys + carries) are *folded*
   k = W/W′ per physical worker (``core/epoch.make_program(fold=k)``), so
   every logical stream continues exactly where it left off;
3. pending delta frames are redistributed sum-preservingly (⊕ is
   commutative/associative over integer frames, and the next reduce-scatter
   only consumes the global sum).

Because the global per-epoch delta and the partition-independent stop
verdict are unchanged, the resumed run's (τ, estimate) is **bit-identical**
to the uninterrupted W-worker run — certified by
``tests/test_serve_session.py``.

Also home to the train-side :func:`elastic_restore` (absorbed from the seed
stub ``runtime/elastic.py``, which remains as a deprecation shim): restore a
model/optimizer checkpoint distributed per the *new* mesh's shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.adaptive import reassemble_shared
from ..core.frames import FrameStrategy
from .session import AdaptiveSession, SessionSpec, StepperCache

PyTree = Any


def elastic_restore(manager: CheckpointManager, tree_like: PyTree,
                    new_shardings: Optional[PyTree]
                    ) -> Optional[Tuple[int, PyTree, dict]]:
    """Restore the latest checkpoint distributed per ``new_shardings``
    (computed for the NEW mesh).  Returns (step, tree, meta) or None.

    Checkpoints are global-slice chunked (``checkpoint/manager.py``) and the
    data pipeline is stateless in ``(step, shard, n_shards)``, so changing
    the data-parallel world size between runs requires nothing beyond
    computing the new shardings and re-distributing."""
    return manager.restore_latest(tree_like, shardings=new_shardings)


def _redistribute(stacked: np.ndarray, new_world: int) -> np.ndarray:
    """Regroup per-worker leaves (P, ...) into (W′, ...) preserving the sum
    along axis 0 — old worker i's contribution lands on new worker i mod W′.
    Handles both down-scale (P > W′: fold-sum) and up-scale (P < W′:
    zero-pad)."""
    P = stacked.shape[0]
    pad = (-P) % new_world
    if pad:
        stacked = np.concatenate(
            [stacked, np.zeros((pad,) + stacked.shape[1:], stacked.dtype)])
    return stacked.reshape(-1, new_world, *stacked.shape[1:]).sum(
        axis=0, dtype=stacked.dtype)


def reshard_state(state, *, old_spec: SessionSpec, new_spec: SessionSpec,
                  template_state) -> Any:
    """Transform a SHARED_FRAME stacked :class:`EpochState` from the old
    physical layout to the new one (see module docstring for the algebra).
    ``template_state`` supplies the new layout's aux shapes (aux is
    recomputed at the next check; it is re-zeroed here)."""
    P = old_spec.world
    W2 = new_spec.world
    lw = old_spec.logical_world or old_spec.world
    F_old = old_spec.frame_shards or P

    def first(x):
        return np.asarray(x)[0]

    # 1. sampling streams: (P[, k_old]) keys → (lw,) logical → (W2[, k]).
    raw = np.asarray(jax.random.key_data(state.key))
    raw = raw.reshape(lw, *raw.shape[-1:])
    new_keys = raw.reshape(W2, lw // W2, -1) if W2 != lw \
        else raw.reshape(lw, -1)
    key = jax.random.wrap_key_data(jax.numpy.asarray(new_keys))

    def regroup_carry(x):
        a = np.asarray(x)
        a = a.reshape(lw, *a.shape[2:]) if a.ndim >= 2 and \
            a.shape[0] == P and old_spec.fold is not None else a
        assert a.shape[0] == lw, (a.shape, lw)
        return a.reshape(W2, lw // W2, *a.shape[1:]) if W2 != lw \
            else a
    carry = jax.tree.map(regroup_carry, state.carry) \
        if state.carry is not None else None

    # 2. consistent total: reassemble old shards → full → contiguous W′
    # blocks (the layout tiled psum_scatter produces).
    def rescatter(x):
        full = reassemble_shared(np.asarray(x), P, F_old)
        if full.ndim == 0:
            return np.broadcast_to(full, (W2,)).copy()
        assert full.shape[0] % W2 == 0, (full.shape, W2)
        return full.reshape(W2, full.shape[0] // W2, *full.shape[1:])
    total_data = jax.tree.map(rescatter, state.total.data)
    total_num = np.broadcast_to(first(state.total.num), (W2,)).copy()

    # 3. pending deltas: full-size per-worker frames; any sum-preserving
    # redistribution is equivalent under the next reduce-scatter.
    pending_data = jax.tree.map(
        lambda x: _redistribute(np.asarray(x), W2), state.pending.data)
    pending_num = _redistribute(np.asarray(state.pending.num), W2)

    # 4. replicated scalars re-tile; aux re-zeros in the new shard shape.
    def tile(x):
        return np.broadcast_to(first(x), (W2,) + np.asarray(x).shape[1:]).copy()

    aux = jax.tree.map(
        lambda s: np.zeros(s.shape, s.dtype), template_state.aux)
    return template_state.__class__(
        key=key, carry=carry,
        total=state.total.__class__(num=jax.numpy.asarray(total_num),
                                    data=jax.tree.map(jax.numpy.asarray,
                                                      total_data)),
        pending=state.pending.__class__(
            num=jax.numpy.asarray(pending_num),
            data=jax.tree.map(jax.numpy.asarray, pending_data)),
        stop=jax.numpy.asarray(tile(state.stop)),
        aux=jax.tree.map(jax.numpy.asarray, aux),
        epoch=jax.numpy.asarray(tile(state.epoch)),
        stop_epoch=jax.numpy.asarray(tile(state.stop_epoch)))


def reshard_session(session: AdaptiveSession, new_world: int, *,
                    substrate: Optional[str] = None,
                    placement: Optional[tuple] = None,
                    cache: Optional[StepperCache] = None) -> AdaptiveSession:
    """Resume ``session`` on ``new_world`` physical workers (SHARED_FRAME).

    ``new_world`` must divide the session's logical width; the returned
    session continues the identical logical trajectory — per-worker shard
    memory becomes Θ(n/W′) — and its final (τ, estimate) is bit-identical
    to the uninterrupted original run.

    ``placement`` pins the resharded session to specific device ids (a
    ``shard_map`` submesh — e.g. the leading half of the lease a
    pressure-driven shrink keeps, see :mod:`repro.serve.placement`); it
    implies ``substrate="shard_map"``.
    """
    spec = session.spec
    if spec.frame_strategy != FrameStrategy.SHARED_FRAME:
        raise ValueError("elastic re-sharding is defined for SHARED_FRAME "
                         f"sessions (got {spec.strategy!r})")
    if not session.started:
        raise ValueError("session has no state to reshard; start() it or "
                         "restore a checkpoint first")
    lw = spec.logical_world or spec.world
    if lw % new_world != 0:
        raise ValueError(f"new_world={new_world} must divide the session's "
                         f"logical world {lw}")
    if placement is not None:
        substrate = "shard_map"
    new_spec = dataclasses.replace(
        spec, world=new_world, logical_world=lw,
        frame_shards=0,            # one contiguous shard per new worker
        placement=None if placement is None else tuple(placement),
        substrate=substrate if substrate is not None else
        (None if new_world != spec.world else spec.substrate))
    resharded = AdaptiveSession.create(new_spec, cache=cache)
    resharded.state = reshard_state(
        session.state, old_spec=spec, new_spec=new_spec,
        template_state=resharded.state_template())
    resharded.wall_s = session.wall_s
    return resharded

"""Roofline terms from compiled artifacts (DESIGN.md §6).

TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
HLO modules are per-device (GSPMD), so

    compute    = flops_per_device    / 197e12     [s]
    memory     = bytes_per_device    / 819e9      [s]
    collective = coll_bytes_per_dev  / 50e9       [s]

Layer-differencing correction: ``cost_analysis()`` counts a scan (while-loop)
body once, so per-cell costs are derived from two small *unrolled* compiles:

    per_layer = cost(L=2, unrolled) − cost(L=1, unrolled)
    total     = cost(L=1, unrolled) + (n_layers − 1) · per_layer

(enc-dec gets a third variant so encoder and decoder layers are differenced
independently).  Memory fit always comes from the full scanned compile.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # bytes/s per chip
    link_bw: float           # bytes/s per link


V5E = HardwareSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                   link_bw=50e9)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops_total: float = 0.0
    useful_ratio: float = 0.0     # MODEL_FLOPS / (flops_per_dev · chips)

    def finalize(self, hw: HardwareSpec = V5E) -> "RooflineTerms":
        self.compute_s = self.flops_per_dev / hw.peak_flops
        self.memory_s = self.bytes_per_dev / hw.hbm_bw
        self.collective_s = self.coll_bytes_per_dev / hw.link_bw
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        hlo_total = self.flops_per_dev * self.chips
        self.useful_ratio = (self.model_flops_total / hlo_total
                             if hlo_total else 0.0)
        return self

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Closed-form MODEL_FLOPS: 6·N·D (train), 2·N·D (prefill),
    2·N·B (decode, per emitted token), N = active params (MoE-aware)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch


def roofline_terms(*, flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, chips: int,
                   cfg: Optional[ModelConfig] = None,
                   shape: Optional[ShapeConfig] = None,
                   hw: HardwareSpec = V5E) -> RooflineTerms:
    mf = model_flops(cfg, shape) if cfg is not None and shape is not None else 0.0
    return RooflineTerms(flops_per_dev=flops_per_dev,
                         bytes_per_dev=bytes_per_dev,
                         coll_bytes_per_dev=coll_bytes_per_dev,
                         chips=chips, model_flops_total=mf).finalize(hw)


def combine_layer_diff(base: Dict[str, float], two: Dict[str, float],
                       n_layers: int) -> Dict[str, float]:
    """total(L) = base + (L−1)·(two − base) for each cost key."""
    out = {}
    for k in base:
        per_layer = two.get(k, 0.0) - base.get(k, 0.0)
        out[k] = base[k] + max(per_layer, 0.0) * (n_layers - 1)
    return out

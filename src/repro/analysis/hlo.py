"""HLO-text analysis: collective operand bytes per class.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module text and sum operand sizes of every

    all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute   (+ their async -start forms)

Loop caveat: instructions inside a ``while`` body are executed trip-count
times but appear once in the text.  The roofline module corrects for this by
**layer-differencing** (compile L=1 and L=2 unrolled variants; see
DESIGN.md §6) instead of trying to recover trip counts from HLO.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[a-z0-9\[\],{}\s]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_OP_NAMES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def parse_shape_bytes(text: str) -> int:
    """Total bytes of every dtype[shape] literal in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-class operand bytes of collectives in the (per-device) module.

    ``-done`` ops are skipped (the matching ``-start`` already counted).
    Returns {op_name: bytes, "total": bytes, "count": n_ops}.
    """
    out: Dict[str, int] = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:
            continue
        m = None
        for op in _OP_NAMES:
            idx = line.find(f" {op}(")
            if idx < 0:
                idx = line.find(f" {op}-start(")
            if idx >= 0:
                m = (op, idx)
                break
        if m is None:
            continue
        op, idx = m
        # operand shapes appear inside the parens following the op name
        paren = line.find("(", idx)
        operands = line[paren:line.find(")", paren) + 1] if paren >= 0 else ""
        b = parse_shape_bytes(operands)
        if b == 0:
            # operands printed without shapes (older form): fall back to the
            # result shape on the lhs
            b = parse_shape_bytes(line[:idx])
        out[op] += b
        count += 1
    out["total"] = sum(out[o] for o in _OP_NAMES if o in out)
    out["count"] = count
    return dict(out)

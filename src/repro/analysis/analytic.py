"""Analytic (kernel-path) roofline terms — the deploy-target cross-check.

``cost_analysis()`` on XLA:CPU reports *pre-fusion-cluster* "bytes accessed":
the f32 attention-score blocks that the Pallas flash kernel keeps in VMEM
are counted as HBM traffic, inflating the memory term by up to an order of
magnitude (§Perf).  This module computes a closed-form HBM-traffic estimate
for the kernelized TPU execution:

* weights: read fwd + re-read (remat) + read bwd + grad write (f32) +
  optimizer moments r/w (train); read once (prefill/decode)
* activations: ~6 residual-stream-sized tensors r/w per layer per pass
* attention: q/k/v/o traffic + KV streamed once per Q block (flash)
* SSM/RG: recurrence inputs/outputs (a, b, h) per layer
* logits/CE and embedding traffic
* decode: full cache read + one-slot write per emitted token

Used for the ``mem_s_kernel`` column of EXPERIMENTS.md §Roofline; dominance
calls in §Perf quote both terms.
"""

from __future__ import annotations

from repro.models.config import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                   model_axis: int = 16) -> float:
    """Estimated HBM bytes per device per step (kernel-path execution)."""
    P = cfg.param_count()
    L = cfg.n_layers + (cfg.enc_layers if cfg.family == "encdec" else 0)
    d = cfg.d_model
    V = cfg.padded_vocab

    if shape.kind == "decode":
        B_loc = max(shape.global_batch // (chips // model_axis), 1)
        total = P / chips * BF16                      # weights read once
        # KV cache (or SSM state) read per token
        if cfg.family == "ssm":
            cache = cfg.n_layers * B_loc * cfg.dinner * cfg.ssm_state * F32
        else:
            sc = min(shape.seq_len, cfg.window or shape.seq_len)
            sc_loc = sc / model_axis
            cache = cfg.n_layers * B_loc * sc_loc * cfg.n_kv * cfg.hd * BF16 * 2
        total += cache * 2 + B_loc * V / chips * BF16  # read+write + logits
        return total

    tokens_loc = shape.seq_len * shape.global_batch / (chips // model_axis)
    # model-parallel shards see 1/model_axis of head/ffn work per token
    tok_work = tokens_loc / model_axis

    passes = 3.0 if shape.kind == "train" else 1.0   # fwd + refwd + bwd
    # weights
    w = P / chips * BF16 * passes
    if shape.kind == "train":
        w += P / chips * (F32 + 3 * F32)             # grads + moments r/w
    # activations: ~6 d-sized tensors r/w per layer per pass
    act = L * tokens_loc * d * BF16 * 6 * passes
    # attention / recurrence
    if cfg.family == "ssm":
        seqmix = cfg.n_layers * tokens_loc * cfg.dinner / model_axis \
            * cfg.ssm_state * F32 * 3 * passes       # a, b, h
    else:
        H_loc = max(cfg.n_heads / model_axis, 1)
        qkvo = (2 * H_loc + 2 * cfg.n_kv) * cfg.hd
        nq = max(shape.seq_len // cfg.attn_chunk, 1)
        window = cfg.window or (cfg.local_window if cfg.family == "hybrid"
                                else 0)
        kv_frac = min(1.0, window / shape.seq_len) if window else 1.0
        stream = cfg.n_kv * cfg.hd * (nq / 2) * kv_frac  # flash KV re-reads
        seqmix = cfg.n_layers * tokens_loc * (qkvo + stream) * BF16 * passes
    # logits + CE (+ embedding gather)
    logits = tokens_loc * V / model_axis * (BF16 + F32) * passes \
        if shape.kind == "train" else \
        shape.global_batch / max(chips // model_axis, 1) * V * BF16
    emb = tokens_loc * d * BF16 * 2
    return w + act + seqmix + logits + emb


def kernel_memory_s(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                    hbm_bw: float = 819e9) -> float:
    return analytic_bytes(cfg, shape, chips) / hbm_bw

from .hlo import collective_bytes, parse_shape_bytes
from .roofline import RooflineTerms, V5E, roofline_terms, model_flops

__all__ = ["collective_bytes", "parse_shape_bytes", "RooflineTerms", "V5E",
           "roofline_terms", "model_flops"]

"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + LLM backbone [arXiv:2404.16821].
ViT frontend is a stub: ``input_specs`` provides 256 precomputed patch
embeddings prepended to the text sequence."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=28672, vocab=128256,
    n_patches=256, grad_accum=4,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-76b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, n_patches=8, grad_accum=1,
        remat="none")

"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) moe_ff=1536
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].
EP sharding: 128 experts / 16-way model axis = 8 experts per shard.
head_dim=128 (as published; H·hd = 8192 ≠ d_model)."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, head_dim=128,
    d_ff=1536, moe_ff=1536, vocab=151936, n_experts=128, top_k=8, grad_accum=4,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-235b-a22b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=96, moe_ff=96, vocab=256,
        n_experts=8, top_k=2, remat="none")

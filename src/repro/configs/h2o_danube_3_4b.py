"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA [arXiv:2401.16818].
head_dim = 120 (3840/32). SWA window 4096 ⇒ long_500k runs."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv=8, d_ff=10240, vocab=32000,
    window=4096, grad_accum=4,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="h2o-danube-3-4b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, window=32, remat="none")

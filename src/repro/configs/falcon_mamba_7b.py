"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free), vocab=65024,
ssm_state=16, mamba1 arch [arXiv:2410.05355]."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv=1, d_ff=0, vocab=65024,
    ssm_state=16, ssm_conv=4, grad_accum=4,  # d_inner=2·d=8192, dt_rank=256 (defaults)
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="falcon-mamba-7b-reduced", n_layers=2, d_model=64,
        vocab=256, ssm_state=4, remat="none")

"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal [arXiv:2308.11596].
Frontend stub: ``input_specs`` provides precomputed frame embeddings
(frames = seq // 4). vocab padded 256206 → 256256 for 16-way TP."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=8192, vocab=256206, frame_ratio=4, grad_accum=4,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="seamless-m4t-large-v2-reduced", n_layers=2,
        enc_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
        remat="none")

"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, attn)
[arXiv:2402.19427]. head_dim=256, lru_width=2560, local window 2048.
26 layers = 8 scanned (rec,rec,attn) units + 2 trailing rec layers."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, d_ff=7680, vocab=256000,
    lru_width=2560, attn_every=3, local_window=2048, grad_accum=4,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-2b-reduced", n_layers=5, d_model=64,
        n_heads=2, n_kv=1, d_ff=128, vocab=256, lru_width=64,
        local_window=32, remat="none")

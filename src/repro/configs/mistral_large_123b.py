"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407].
Pure full attention ⇒ long_500k skipped (DESIGN.md §4)."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv=8, d_ff=28672, vocab=32768,
    grad_accum=8,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mistral-large-123b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, grad_accum=1, remat="none")

"""One module per assigned architecture (+ the paper's own KADABRA config).

Each module registers a :class:`repro.models.ModelConfig` with the exact
published dimensions, plus a ``reduced()`` factory for CPU smoke tests.
"""

"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297]."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=92544, grad_accum=2,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internlm2-20b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, remat="none")

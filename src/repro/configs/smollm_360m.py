"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM family].
15 heads don't divide the 16-way model axis → heads replicate, ffn/vocab
shard (automatic divisibility fallback)."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv=5, d_ff=2560, vocab=49152, grad_accum=2,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-360m-reduced", n_layers=2, d_model=60,
        n_heads=3, n_kv=1, d_ff=128, vocab=256, remat="none")

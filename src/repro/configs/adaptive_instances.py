"""Presets for the ADS instance layer (``repro.core.instances``).

Mirrors ``configs/kadabra_bc.py`` for the non-KADABRA workloads: each preset
is a frozen instance object ready for ``register_instance`` (or direct
``build()``), sized either for CI-speed conformance runs (the registry
defaults) or for benchmark-scale measurements.
"""

from __future__ import annotations

from repro.core.instances import (DiameterInstance, GradVarianceInstance,
                                  KadabraInstance, ReachabilityInstance,
                                  TrianglesInstance, WeightedSamplingInstance)

# Conformance-sized (the registry defaults — tiny, exact oracles feasible).
CONFORMANCE = {
    "kadabra": KadabraInstance(),
    "triangles": TrianglesInstance(),
    "reachability": ReachabilityInstance(),
    "wrs": WeightedSamplingInstance(),
    "diameter": DiameterInstance(),
    "gradvar": GradVarianceInstance(),
}

# Benchmark-sized: big enough that strategy differences show up in wall
# time, still CPU-tractable.  Expensive exact oracles are NOT computed at
# this scale; the conformance harness is the correctness gate, these are
# for timing.
BENCH = {
    "kadabra-m": KadabraInstance(name="kadabra-m", n_vertices=512,
                                 n_edges=2048, eps=0.05, batch=64,
                                 compute_oracle=False),
    "triangles-m": TrianglesInstance(name="triangles-m", n_vertices=2048,
                                     m_per=4, eps_p=0.02, batch=256,
                                     compute_oracle=False),
    "reachability-m": ReachabilityInstance(name="reachability-m", rows=4,
                                           cols=4, t=15, eps=0.02,
                                           batch=256, compute_oracle=False),
    # WRS oracle is O(n) — always computed; max_samples keeps the int32
    # moment sums exact (max_samples·(value_scale−1)² < 2³¹).
    "wrs-m": WeightedSamplingInstance(name="wrs-m", n_items=1 << 16,
                                      rtol=0.01, batch=4096,
                                      max_samples=1 << 19),
    "diameter-m": DiameterInstance(name="diameter-m", kind="er",
                                   n_vertices=512, n_edges=2048,
                                   graph_seed=7, gap=2, batch=32,
                                   max_samples=8192, compute_oracle=False),
    # gradvar oracle is O(n) — always computed.
    "gradvar-m": GradVarianceInstance(name="gradvar-m", n_examples=1 << 14,
                                      dim=32, rtol=0.01, batch=1024,
                                      max_samples=1 << 19),
}

"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088].
TP-MoE sharding (8 experts don't divide the 16-way model axis — the
per-expert ffn dim shards instead; see DESIGN.md §3.2)."""
import dataclasses

from repro.models import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, window=4096, grad_accum=8,
))


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mixtral-8x22b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, n_experts=4, top_k=2,
        window=32, remat="none")

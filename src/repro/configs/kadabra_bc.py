"""The paper's own workload: KADABRA betweenness-centrality approximation.

Not an LM architecture — this config parameterizes the case-study benchmarks
and examples (graph size classes from App. E, matched synthetically)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class KadabraBCConfig:
    graph_kind: str = "er"        # er | ba | grid
    n_vertices: int = 1_000
    n_edges: int = 5_000
    eps: float = 0.03
    delta: float = 0.1
    batch: int = 32
    rounds_per_epoch: int = 4     # N (App. C.2) in rounds
    xi: float = 1.33              # App. C.3
    world: int = 8                # virtual workers


PRESETS = {
    "moderate": KadabraBCConfig(n_vertices=2_000, n_edges=10_000),
    "road": KadabraBCConfig(graph_kind="grid", n_vertices=2_500,
                            n_edges=0, eps=0.05),
    "social": KadabraBCConfig(graph_kind="ba", n_vertices=3_000,
                              n_edges=9_000, eps=0.03),
}

"""Deprecation shim — elastic rescaling moved to :mod:`repro.serve.elastic`.

The serving subsystem owns elasticity now: :func:`elastic_restore` (restore
a checkpoint onto a different mesh) lives next to the adaptive-session
re-sharding path (:func:`repro.serve.elastic.reshard_session`).  This module
re-exports the old name so existing imports keep working.
"""

from __future__ import annotations

from ..serve.elastic import elastic_restore

__all__ = ["elastic_restore"]

"""Elastic rescaling: restore a checkpoint onto a different mesh.

Because checkpoints are global-slice chunked (``checkpoint/manager.py``) and
the data pipeline is stateless in ``(step, shard, n_shards)``, changing the
data-parallel world size between runs requires nothing beyond computing the
new shardings and re-distributing — this helper does exactly that.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple


from repro.checkpoint import CheckpointManager

PyTree = Any


def elastic_restore(manager: CheckpointManager, tree_like: PyTree,
                    new_shardings: Optional[PyTree]
                    ) -> Optional[Tuple[int, PyTree, dict]]:
    """Restore the latest checkpoint distributed per ``new_shardings``
    (computed for the NEW mesh).  Returns (step, tree, meta) or None."""
    return manager.restore_latest(tree_like, shardings=new_shardings)

"""Fault-tolerance runtime for the host training loop.

At thousand-node scale the interesting events are

* **fail-stop** — a worker (or pod) dies: the loop must restore from the
  last checkpoint and *replay the data cursor* (exactly-once semantics come
  from the stateless pipeline, ``data/pipeline.py``).
* **stragglers** — a slow worker: the epoch engine already absorbs these
  *within* a step (frames carry their own ``num``; a slow worker publishes a
  smaller frame — paper §3.3 / DESIGN.md §2).  Across steps, the
  ``Heartbeat`` watchdog flags persistent stragglers for replacement.
* **preemption** — same recovery path as fail-stop.

On this single-process container the injector *simulates* the events so the
recovery path is exercised end-to-end by tests and ``launch/train.py
--inject-failures``; on a real fleet the same hooks attach to
``jax.distributed`` runtime errors.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Optional

import numpy as np


class FailureEvent(enum.Enum):
    NONE = "none"
    WORKER_CRASH = "worker_crash"      # fail-stop → restore + replay
    STRAGGLER = "straggler"            # slow worker → smaller frame
    PREEMPTION = "preemption"          # planned eviction → checkpoint + exit


@dataclasses.dataclass
class FailureInjector:
    seed: int = 0
    crash_prob: float = 0.0
    straggler_prob: float = 0.0
    preempt_at_step: Optional[int] = None

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def poll(self, step: int) -> FailureEvent:
        if self.preempt_at_step is not None and step == self.preempt_at_step:
            return FailureEvent.PREEMPTION
        u = self._rng.random()
        if u < self.crash_prob:
            return FailureEvent.WORKER_CRASH
        if u < self.crash_prob + self.straggler_prob:
            return FailureEvent.STRAGGLER
        return FailureEvent.NONE


class Heartbeat:
    """Wall-clock watchdog: flags steps exceeding ``deadline_s`` (straggler /
    hang detection for the host loop)."""

    def __init__(self, deadline_s: float,
                 on_late: Optional[Callable[[float], None]] = None):
        self.deadline_s = deadline_s
        self.on_late = on_late or (lambda dt: None)
        self._t0: Optional[float] = None
        self._late_steps = 0

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> float:
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        if dt > self.deadline_s:
            self._late_steps += 1
            self.on_late(dt)
        self._t0 = None
        return dt

    @property
    def late_steps(self) -> int:
        return self._late_steps

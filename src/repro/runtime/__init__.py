from .failures import FailureInjector, FailureEvent, Heartbeat
from .elastic import elastic_restore

__all__ = ["FailureInjector", "FailureEvent", "Heartbeat", "elastic_restore"]

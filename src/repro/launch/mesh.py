"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the real single device.
"""

from __future__ import annotations

from ..core.compat import make_mesh as _compat_make_mesh
from ..core.substrate import WORKER_AXIS, worker_mesh as _worker_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2,2))."""
    return _compat_make_mesh(shape, axes)


def make_worker_mesh(world: int, axis: str = WORKER_AXIS, devices=None):
    """1-D mesh of ``world`` devices for the epoch engine's shard_map
    substrate (raises with the XLA_FLAGS hint when the host has fewer
    devices — see core/substrate.py).  ``devices`` pins the mesh to an
    explicit device list — e.g. a placement-pool lease."""
    return _worker_mesh(world, axis, devices=devices)


def make_device_pool(topology: str = "auto"):
    """A :class:`repro.serve.placement.DevicePool` over the machine
    topology — ``"auto"`` reads the live JAX runtime (grouped by process),
    ``"N"``/``"GxN"`` build abstract pools (see ``DeviceTopology.parse``).
    Lease → mesh binding happens through ``SessionSpec.placement`` (the
    session build calls ``worker_mesh(devices=...)`` itself)."""
    from ..serve.placement import DevicePool, DeviceTopology
    return DevicePool(DeviceTopology.parse(topology))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")

"""Pipeline parallelism (GPipe-style) over a mesh axis — the >512-chip
scaling path sketched in DESIGN.md §9.

``pipeline_forward`` runs a scanned layer stack split into S stages along a
mesh axis: each stage holds n_layers/S of the (stacked) weights; microbatch
activations flow stage-to-stage with ``jax.lax.ppermute`` inside a
``shard_map``.  The classic GPipe schedule processes M microbatches in
M + S − 1 ticks (bubble fraction (S−1)/(M+S−1)).

This is the inter-pod configuration for very deep models: mesh
(stage, data, model) with DCN crossing only between consecutive stages
(point-to-point, not all-reduce) — the cheapest possible inter-pod traffic
pattern.  Shipped as a first-class prototype with tests; the per-arch
launch configs keep pod-DP as the default (DESIGN.md §9 rationale).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map

PyTree = Any


def pipeline_forward(layer_fn: Callable, stacked_params: PyTree,
                     x_micro: jax.Array, mesh, axis: str = "stage"
                     ) -> jax.Array:
    """Run x through L layers split across the ``axis`` mesh dim.

    layer_fn(lp, x) -> x'  — one layer.
    stacked_params — leaves with leading dim L (L % n_stages == 0).
    x_micro — (M, mb, …) microbatched activations, M ≥ n_stages.
    Returns (M, mb, …) outputs after all L layers.
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    assert M >= S, f"need ≥ {S} microbatches to fill the pipeline"
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % S == 0

    def stage_fn(lp_stage, xs):
        # lp_stage: this stage's (L/S, …) weights; xs: (M, mb, …)
        sid = jax.lax.axis_index(axis)
        n_ticks = M + S - 1
        fwd_perm = [(i, i + 1) for i in range(S - 1)]

        def run_stage(x):
            def body(x, lp):
                return layer_fn(lp, x), None
            x, _ = jax.lax.scan(body, x, lp_stage)
            return x

        def tick(carry, t):
            outs, inflight = carry
            # stage 0 injects microbatch t (others use the permuted input)
            mb_idx = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(sid == 0, xs[mb_idx], inflight)
            y = run_stage(x_in)
            # last stage emits microbatch (t − S + 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            emit = jnp.logical_and(sid == S - 1, t >= S - 1)
            outs = jax.tree.map(
                lambda o, v: o.at[out_idx].set(
                    jnp.where(emit, v, o[out_idx])), outs, y)
            nxt = jax.lax.ppermute(y, axis, fwd_perm)
            return (outs, nxt), None

        outs0 = jnp.zeros_like(xs)
        inflight0 = jnp.zeros_like(xs[0])
        (outs, _), _ = jax.lax.scan(tick, (outs0, inflight0),
                                    jnp.arange(n_ticks))
        # replicate the last stage's outputs to every stage (masked psum —
        # ppermute needs a bijection, so it can't broadcast)
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    # stage s holds layers [s·L/S, (s+1)·L/S)
    in_specs = (jax.tree.map(lambda _: P(axis), stacked_params), P())
    fn = shard_map(stage_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=P(), check_vma=False)
    return fn(stacked_params, x_micro)

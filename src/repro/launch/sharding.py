"""Sharding policy: logical axes → mesh axes, per architecture.

The policy is a small, inspectable table (hillclimbing edits happen here).
Divisibility fallback lives in :class:`repro.models.layers.ShardingRules`,
so one table serves all ten architectures.

Parallelism provided (DESIGN.md §3.2):
* DP   — batch over ``pod``×``data``
* TP   — heads / kv / ffn / experts / vocab / d_inner / lru over ``model``
* SP   — residual-stream sequence over ``model`` between layers (opt-in)
* EP   — experts over ``model`` when the count divides (else TP-MoE)
* FSDP — weight ``embed`` dim additionally over ``data`` (ZeRO-3-style),
         opt-in per arch size; optimizer state is sharded likewise (ZeRO-1
         comes for free since opt state mirrors param specs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.models.layers import ShardingRules


@dataclasses.dataclass(frozen=True)
class PolicyFlags:
    fsdp: bool = False             # shard weights' "embed" dim over data axes
    seq_parallel: bool = False     # shard residual seq over model axis
    zero1: bool = True             # optimizer state sharded like FSDP even
                                   # when weights are not (applied in optim)
    dp_over_model: bool = False    # small archs: replicate weights, use the
                                   # model axis as extra DP (batch spreads
                                   # over pod×data×model) — avoids the 16×
                                   # replicated-attention waste when heads
                                   # don't divide the model axis (§Perf)


def default_flags(cfg: ModelConfig) -> PolicyFlags:
    # Baseline policy (paper-faithful Megatron TP + DP + FSDP-when-big).
    # dp_over_model stays False here — it is a §Perf hillclimb flag applied
    # explicitly via ``dryrun --opt`` so the before/after is measurable.
    big = cfg.param_count() * 2 > 12e9   # >12 GB of bf16 weights
    return PolicyFlags(fsdp=big, seq_parallel=big)


def build_rules(cfg: ModelConfig, mesh: Mesh,
                flags: Optional[PolicyFlags] = None) -> ShardingRules:
    flags = flags or default_flags(cfg)
    dp: Tuple[str, ...] = tuple(a for a in mesh.axis_names if a != "model")
    tp = ("model",) if "model" in mesh.axis_names else ()
    if flags.dp_over_model:
        dp = tuple(mesh.axis_names)   # model axis becomes extra DP
        tp = ()                       # weights fully replicated

    rules: Dict[str, Tuple[str, ...]] = {
        # ---- weights
        "vocab": tp,
        "heads": tp,
        "kv": tp,
        "ffn": tp,
        "experts": tp,     # EP when divisible; fallback replicates → TP path
        "inner": tp,       # mamba d_inner
        "inner2": tp,      # mamba in_proj fused 2·d_inner
        "lru": tp,
        "lru_in": (),      # second dim of square lru gate weights
        "embed": dp if flags.fsdp else (),
        "layers": (),
        # ---- activations
        "batch": dp,
        "heads_act": tp,
        "ffn_act": tp,
        "experts_act": tp,   # EP dispatch target (divisibility-checked)
        "seq_sp": tp if flags.seq_parallel else (),
        # decode KV caches are always sequence-sharded over the model axis
        # (flash-decoding; GQA kv-head counts don't divide 16 — DESIGN §3.2)
        "seq_kv": tp,
    }
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ShardingRules(rules=rules, mesh_shape=mesh_shape)


def batch_specs(cfg: ModelConfig, mesh: Mesh, kind: str,
                rules: Optional[ShardingRules] = None):
    """PartitionSpecs for the input batch pytree (see launch/specs.py)."""
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dps = dp if len(dp) > 1 else (dp[0] if dp else None)
    if kind == "decode":
        tok = P(dps)
        return {"tokens": tok, "pos": tok}
    specs = {"tokens": P(dps, None), "labels": P(dps, None)}
    if cfg.family == "vlm":
        specs["patches"] = P(dps, None, None)
    if cfg.family == "encdec":
        specs["frames"] = P(dps, None, None)
    if kind == "prefill":
        specs.pop("labels")
    return specs

"""ShapeDtypeStruct stand-ins + shardings for every model input.

``input_specs(arch, shape, mesh)`` returns (kwargs of ShapeDtypeStructs,
matching in_shardings) for the step function that the given shape lowers:
``train_step(params, opt_state, batch)``, ``prefill_step(params, batch)``
or ``serve_step(params, cache, batch)``.  No device memory is allocated.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Model, ModelConfig, ShapeConfig
from repro.models.layers import ParamDef, ShardingRules
from repro.launch.sharding import PolicyFlags, build_rules, default_flags

PyTree = Any

# logical axes of each batch entry
_BATCH_LOGICAL = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "patches": ("batch", None, None),
    "frames": ("batch", None, None),
}
_DECODE_LOGICAL = {"tokens": ("batch",), "pos": ("batch",)}

# logical axes of cache entries, keyed by (family-kind, key)
_CACHE_LOGICAL = {
    "k": (None, "batch", "seq_kv", None, None),
    "v": (None, "batch", "seq_kv", None, None),
    "cross_k": (None, "batch", "seq_kv", None, None),
    "cross_v": (None, "batch", "seq_kv", None, None),
    "kpos": ("batch", "seq_kv"),
    "h_ssm": (None, "batch", "inner", None),
    "conv_ssm": (None, "batch", None, "inner"),
    "h_hyb": (None, None, "batch", "lru"),
    "conv_hyb": (None, None, "batch", None, "lru"),
    "tail_h": (None, "batch", "lru"),
    "tail_conv": (None, "batch", None, "lru"),
}


def _cache_logical(cfg: ModelConfig, key: str) -> Tuple:
    if key in ("h", "conv"):
        suffix = "_ssm" if cfg.family == "ssm" else "_hyb"
        return _CACHE_LOGICAL[key + suffix]
    return _CACHE_LOGICAL[key]


def microbatched(shape: ShapeConfig, accum: int) -> Tuple[int, int]:
    """(n_micro, per-micro batch) for train shapes."""
    a = max(1, accum)
    while shape.global_batch % a != 0:
        a -= 1
    return a, shape.global_batch // a


def batch_struct(cfg: ModelConfig, shape: ShapeConfig,
                 micro: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one batch of the given shape.

    For train shapes with grad_accum > 1 the leading dim is
    (n_micro, micro_batch, …) — the step scans microbatches.
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((B,), jnp.int32), "pos": sds((B,), jnp.int32)}

    lead: Tuple[int, ...]
    if shape.kind == "train" and (micro or cfg.grad_accum) > 1:
        n_micro, mb = microbatched(shape, micro or cfg.grad_accum)
        lead = (n_micro, mb)
    else:
        lead = (B,)

    out: Dict[str, jax.ShapeDtypeStruct] = {}
    text_len = S
    if cfg.family == "vlm":
        text_len = S - cfg.n_patches
        out["patches"] = sds(lead + (cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = sds(lead + (S // cfg.frame_ratio, cfg.d_model),
                            jnp.bfloat16)
    out["tokens"] = sds(lead + (text_len,), jnp.int32)
    if shape.kind == "train":
        out["labels"] = sds(lead + (text_len,), jnp.int32)
    return out


def _spec_with_micro(rules: ShardingRules, shape_t: Tuple[int, ...],
                     logical: Tuple, micro: bool) -> P:
    if micro:  # leading (n_micro, mb, …): n_micro replicated, mb = batch
        logical = (None,) + logical
    return rules.spec_for_shape(shape_t, logical)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: ShardingRules,
                    structs: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, Any]:
    logical_map = _DECODE_LOGICAL if shape.kind == "decode" else _BATCH_LOGICAL
    micro = shape.kind == "train" and cfg.grad_accum > 1
    return {
        k: NamedSharding(mesh, _spec_with_micro(rules, v.shape,
                                                logical_map[k], micro))
        for k, v in structs.items()
    }


def opt_rules(rules: ShardingRules, mesh: Mesh,
              flags: PolicyFlags) -> ShardingRules:
    """ZeRO-1: optimizer state shards its 'embed' dim over the data axes even
    when the weights do not (flags.zero1)."""
    if not flags.zero1:
        return rules
    dp = tuple(a for a in mesh.axis_names if a != "model")
    r = dict(rules.rules)
    if not r.get("embed"):
        r["embed"] = dp
    return ShardingRules(rules=r, mesh_shape=rules.mesh_shape)


def input_specs(arch: str | ModelConfig, shape: ShapeConfig, mesh: Mesh,
                flags: Optional[PolicyFlags] = None):
    """→ (kwargs: dict of SDS pytrees, in_shardings: matching dict,
         rules, model).  kwargs match the step function signature for
         ``shape.kind``."""
    from repro.models import get_config
    cfg = arch if isinstance(arch, ModelConfig) else get_config(arch)
    flags = flags or default_flags(cfg)
    rules = build_rules(cfg, mesh, flags)
    model = Model(cfg, rules)
    defs = model.param_defs()
    params = model.abstract()
    pspecs = jax.tree.map(lambda d: NamedSharding(mesh, rules.spec_for(d)),
                          defs, is_leaf=lambda x: isinstance(x, ParamDef))

    bstruct = batch_struct(cfg, shape)
    bshard = batch_shardings(cfg, shape, mesh, rules, bstruct)

    if shape.kind == "train":
        orules = opt_rules(rules, mesh, flags)
        ospecs_leaf = jax.tree.map(
            lambda d: NamedSharding(mesh, orules.spec_for(d)), defs,
            is_leaf=lambda x: isinstance(x, ParamDef))
        def f32(t):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
        from repro.optim.adamw import AdamWState
        opt_state = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                               mu=f32(params), nu=f32(params))
        opt_shard = AdamWState(
            step=NamedSharding(mesh, P()), mu=ospecs_leaf, nu=ospecs_leaf)
        kwargs = {"params": params, "opt_state": opt_state, "batch": bstruct}
        shardings = {"params": pspecs, "opt_state": opt_shard,
                     "batch": bshard}
    elif shape.kind == "prefill":
        kwargs = {"params": params, "batch": bstruct}
        shardings = {"params": pspecs, "batch": bshard}
    else:  # decode
        cache = jax.eval_shape(
            lambda: Model(cfg, None).init_cache(shape.global_batch,
                                                shape.seq_len))
        cshard = {
            k: NamedSharding(
                mesh, rules.spec_for_shape(v.shape, _cache_logical(cfg, k)))
            for k, v in cache.items()
        }
        kwargs = {"params": params, "cache": cache, "batch": bstruct}
        shardings = {"params": pspecs, "cache": cshard, "batch": bshard}
    return kwargs, shardings, rules, model

"""End-to-end training launcher.

Composes every substrate: deterministic data pipeline → (optionally
*adaptive*) gradient accumulation → AdamW → async checkpointing → failure
injection/recovery.  CPU-runnable with the reduced configs; the same loop
drives the production mesh on real hardware (the step fn is the one the
dry-run lowers).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m-reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 20

``--adaptive`` switches gradient accumulation to the paper's ADS engine
(stop drawing microbatches once the gradient-variance bound holds).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataCursor, TokenStream
from repro.models import Model, get_config
from repro.optim import (AdamWConfig, AdaptiveAccumConfig, adamw_init,
                         adaptive_accumulate)
from repro.optim.adamw import adamw_update
from repro.runtime import FailureEvent, FailureInjector, Heartbeat


def _resolve_config(name: str):
    if name.endswith("-reduced"):
        import importlib
        mod = name[: -len("-reduced")].replace("-", "_")
        return importlib.import_module(f"repro.configs.{mod}").reduced()
    return get_config(name)


def make_adaptive_step(model: Model, opt_cfg: AdamWConfig,
                       acc_cfg: AdaptiveAccumConfig):
    def loss_and_grad(params, batch):
        return jax.value_and_grad(model.train_loss)(params, batch)

    def step(params, opt_state, micro_batches):
        grads, loss, n_used, rel = adaptive_accumulate(
            lambda p, b: loss_and_grad(p, b), params, micro_batches, acc_cfg)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "micro_used": n_used, "rel_sem": rel}

    return step


def make_fixed_step(model: Model, opt_cfg: AdamWConfig):
    from repro.launch.steps import make_train_step
    return make_train_step(model, opt_cfg)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-reduced")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adaptive", action="store_true",
                    help="ADS-driven gradient accumulation")
    ap.add_argument("--rtol", type=float, default=0.3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--preempt-at", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = _resolve_config(args.arch)
    cfg = dataclasses.replace(cfg, grad_accum=1)
    model = Model(cfg, None)
    opt_cfg = AdamWConfig(lr=args.lr)
    acc_cfg = AdaptiveAccumConfig(rtol=args.rtol,
                                  min_micro=min(2, args.micro),
                                  max_micro=args.micro)

    params = model.init(jax.random.key(args.seed))
    opt_state = adamw_init(params)
    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         batch=args.batch, seed=args.seed)
    cursor = DataCursor(step=0, seed=args.seed)

    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(Path(args.ckpt_dir), keep=2)
        if args.resume:
            restored = manager.restore_latest({"params": params,
                                               "opt": opt_state})
            if restored:
                step0, tree, meta = restored
                params, opt_state = tree["params"], tree["opt"]
                cursor = DataCursor.from_meta(meta)
                print(f"[train] resumed at step {step0} "
                      f"(data cursor {cursor.step})")

    injector = FailureInjector(
        seed=args.seed + 1,
        crash_prob=0.02 if args.inject_failures else 0.0,
        straggler_prob=0.05 if args.inject_failures else 0.0,
        preempt_at_step=args.preempt_at if args.preempt_at >= 0 else None)
    heartbeat = Heartbeat(deadline_s=120.0, on_late=lambda dt: print(
        f"[train] WARN slow step: {dt:.1f}s (straggler suspect)"))

    step_fn = jax.jit(make_adaptive_step(model, opt_cfg, acc_cfg)
                      if args.adaptive else make_fixed_step(model, opt_cfg),
                      donate_argnums=(0, 1))

    t_start = time.time()
    step = cursor.step
    losses = []
    while step < args.steps:
        heartbeat.start()
        event = injector.poll(step)
        if event == FailureEvent.WORKER_CRASH and manager is not None:
            print(f"[train] step {step}: injected WORKER_CRASH — "
                  f"restoring from last checkpoint")
            restored = manager.restore_latest({"params": params,
                                               "opt": opt_state})
            if restored:
                _, tree, meta = restored
                params, opt_state = tree["params"], tree["opt"]
                cursor = DataCursor.from_meta(meta)
                step = cursor.step
        if event == FailureEvent.PREEMPTION and manager is not None:
            print(f"[train] step {step}: PREEMPTION — checkpoint + exit")
            manager.save({"params": params, "opt": opt_state}, step,
                         meta=DataCursor(step=step, seed=args.seed).as_meta())
            manager.wait()
            return 0

        batch = stream.micro_batches(jnp.int32(step), args.micro)
        if not args.adaptive:
            if args.micro == 1:
                batch = jax.tree.map(lambda x: x[0], batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = heartbeat.stop()
        if step % args.log_every == 0 or step == args.steps - 1:
            extra = ""
            if args.adaptive:
                extra = (f" micro={int(metrics['micro_used'])}"
                         f" rel_sem={float(metrics['rel_sem']):.3f}")
            print(f"[train] step {step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:6.0f}ms{extra}")
        step += 1
        if manager is not None and step % args.ckpt_every == 0:
            manager.save({"params": params, "opt": opt_state}, step,
                         meta=DataCursor(step=step, seed=args.seed).as_meta())
    if manager is not None:
        manager.save({"params": params, "opt": opt_state}, step,
                     meta=DataCursor(step=step, seed=args.seed).as_meta())
        manager.wait()
    n = max(len(losses) // 10, 1)
    print(f"[train] done in {time.time()-t_start:.1f}s; "
          f"loss {sum(losses[:n])/n:.4f} → {sum(losses[-n:])/n:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

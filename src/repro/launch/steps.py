"""Step functions: train (grad-accum scan + AdamW), prefill, decode.

These are the functions the dry-run lowers and the launchers jit.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update

PyTree = Any


def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None
                    ) -> Callable:
    """train_step(params, opt_state, batch) → (params, opt_state, metrics).

    ``batch`` leaves are (B, …) or (n_micro, mb, …); microbatches are scanned
    with f32 gradient accumulation (the associative ∘ of the paper's
    framework — ``optim.adaptive_accumulate`` is the adaptive variant used by
    the training loop; the fixed scan is what the dry-run lowers).
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def train_step(params, opt_state: AdamWState, batch):
        tokens = batch["tokens"]
        if tokens.ndim == 3:  # (n_micro, mb, S): scan with accumulation
            n_micro = tokens.shape[0]

            def micro(acc, mb):
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum, lsum = acc
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), batch)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt, gnorm = adamw_update(grads, opt_state, params,
                                                  opt_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": new_opt.step}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        _, logits = model.prefill(params, batch)
        return logits  # (B, V) last-position logits

    return prefill_step


def make_serve_step(model: Model, greedy: bool = True) -> Callable:
    """serve_step(params, cache, batch) → (cache', next_tokens)."""

    def serve_step(params, cache, batch):
        cache, logits = model.decode_step(params, cache, batch)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cache, nxt

    return serve_step


def step_for(model: Model, kind: str) -> Callable:
    if kind == "train":
        return make_train_step(model)
    if kind == "prefill":
        return make_prefill_step(model)
    return make_serve_step(model)

"""Serving launcher: the adaptive-query pool (the serving subsystem's CLI),
batched prefill + decode, and **adaptive metric evaluation** — the paper's
ADS engine estimating a serve-side metric (mean per-token loss over a
prompt distribution) to (ε,δ) with empirical-Bernstein stopping instead of
a fixed eval-set sweep.

    # epoch-granular continuous batching over a mixed query stream
    PYTHONPATH=src python -m repro.launch.serve --pool \
        --queries wrs:shared:4,triangles:local:2:1 --max-in-flight 2 \
        [--checkpoint-dir CKPT [--resume] [--checkpoint-every 2]]
    # placement-aware: disjoint submeshes + pressure-driven elasticity
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --pool --substrate shard_map \
        --topology auto --pressure-policy shrink-regrow \
        --queries reachability:shared:4,reachability:shared:4:1,wrs:local:2
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m-reduced \
        --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m-reduced \
        --adaptive-eval --eps 0.1 --delta 0.1
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.epoch import EpochConfig, run_worker
from repro.core.frames import FrameStrategy, StateFrame, sequential_collectives
from repro.core.stopping import EmpiricalBernsteinCondition
from repro.data import TokenStream
from repro.models import Model


def _resolve_config(name: str):
    from repro.launch.train import _resolve_config as r
    return r(name)


def generate(model: Model, params, prompts: jax.Array, gen: int):
    """Greedy decode ``gen`` tokens for a (B, P) prompt batch."""
    cfg = model.cfg
    B, P = prompts.shape
    capacity = P + gen
    cache = model.init_cache(B, capacity)

    @partial(jax.jit, donate_argnums=(0,))
    def one(cache, tok, pos):
        return model.decode_step(params, cache, {"tokens": tok, "pos": pos})

    toks = prompts[:, 0]
    out = [toks]
    for t in range(capacity - 1):
        pos = jnp.full((B,), t, jnp.int32)
        cache, logits = one(cache, toks, pos)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = jnp.where(t + 1 < P, prompts[:, min(t + 1, P - 1)], nxt)
        out.append(toks)
    return jnp.stack(out, axis=1)  # (B, P+gen)


def adaptive_eval(model: Model, params, stream: TokenStream, *,
                  eps: float, delta: float, batch: int, seq: int,
                  max_epochs: int = 200):
    """(ε,δ)-estimate of mean per-token loss via the epoch engine."""
    cond = EmpiricalBernsteinCondition(eps=eps, delta=delta, value_range=15.0)

    @jax.jit
    def loss_of(params, tokens, labels):
        return model.train_loss(params, {"tokens": tokens, "labels": labels})

    def sample_fn(key, carry):
        step = jax.random.randint(key, (), 0, 1 << 30)
        b = stream.batch_at(step)
        l = loss_of(params, b["tokens"], b["labels"])
        return StateFrame(num=jnp.int32(1),
                          data={"s1": l, "s2": jnp.square(l)}), carry

    template = {"s1": jnp.zeros((), jnp.float32),
                "s2": jnp.zeros((), jnp.float32)}
    cfg = EpochConfig(strategy=FrameStrategy.LOCAL_FRAME, rounds_per_epoch=2,
                      max_epochs=max_epochs)
    st = run_worker(sample_fn, cond, template, None, jax.random.key(0), cfg,
                    colls=sequential_collectives())
    tau = float(st.total.num)
    mean = float(st.total.data["s1"]) / max(tau, 1.0)
    return mean, tau, bool(st.stop)


DEFAULT_POOL_QUERIES = "wrs:local:2,triangles:local:2:1"


def serve_pool(args) -> int:
    """Drive the epoch-granular scheduler over a query stream."""
    from repro.launch.mesh import make_device_pool
    from repro.serve import EpochScheduler, PressurePolicy, SessionSpec

    # --resume restores the checkpointed stream; the default query list only
    # applies to fresh pools (explicit --queries adds to a resumed one).
    queries = args.queries if args.queries is not None \
        else ("" if args.resume else DEFAULT_POOL_QUERIES)

    pool = make_device_pool(args.topology) if args.topology else None
    pressure = PressurePolicy.parse(args.pressure_policy)
    if pressure is not None and pool is None:
        print("[serve] --pressure-policy needs --topology (a device pool)")
        return 2
    if pool is not None:
        print(f"[serve] device pool: {pool.capacity} slot(s) in "
              f"{len(pool.topology.groups)} group(s)"
              + (f", pressure={args.pressure_policy}" if pressure else ""))

    if args.resume:
        if not args.checkpoint_dir:
            print("[serve] --resume needs --checkpoint-dir")
            return 2
        sched = EpochScheduler.resume(
            args.checkpoint_dir, max_in_flight=args.max_in_flight,
            substrate=args.substrate, pool=pool, pressure=pressure,
            checkpoint_every=args.checkpoint_every)
        print(f"[serve] resumed {sched.pending} session(s) from "
              f"{args.checkpoint_dir}")
    else:
        sched = EpochScheduler(max_in_flight=args.max_in_flight,
                               substrate=args.substrate,
                               pool=pool, pressure=pressure,
                               checkpoint_dir=args.checkpoint_dir or None,
                               checkpoint_every=args.checkpoint_every)
    for q in (s for s in queries.split(",") if s):
        sched.submit(SessionSpec.parse(q))

    t0 = time.time()
    while not sched.idle:
        ev = sched.tick()
        for qid, old_w, new_w in ev.resharded:
            word = "shrunk" if new_w < old_w else "regrown"
            print(f"[serve] tick {ev.tick}: {word} {qid} "
                  f"W={old_w} → {new_w} (pressure)")
        for qid in ev.retired:
            r = sched.results[qid]
            est = np.array2string(r.estimate, precision=4)
            place = f" dev={r.devices_leased}" \
                f" pwait={r.placement_wait_ticks}" if pool else ""
            print(f"[serve] tick {ev.tick}: retired {qid} "
                  f"τ={r.tau} epochs={r.epochs} wait={r.wait_ticks}"
                  f"{place} est={est}")
    dt = time.time() - t0
    n = len(sched.results)
    taus = sum(r.tau for r in sched.results.values())
    print(f"[serve] pool drained: {n} queries, {sched.tick_count} ticks, "
          f"{taus} samples in {dt:.1f}s ({taus / max(dt, 1e-9):.0f} "
          f"samples/s, {len(sched.cache)} compiled steppers)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adaptive-eval", action="store_true")
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--seq", type=int, default=64)
    # ----- adaptive-query pool (repro.serve scheduler) -----
    ap.add_argument("--pool", action="store_true",
                    help="run the adaptive-query pool scheduler")
    ap.add_argument("--queries", default=None,
                    help="comma-separated instance:strategy:world[:seed] "
                         f"(default for fresh pools: {DEFAULT_POOL_QUERIES}; "
                         "--resume defaults to the restored stream only)")
    ap.add_argument("--max-in-flight", type=int, default=2)
    ap.add_argument("--substrate", default=None)
    ap.add_argument("--topology", default="",
                    help="device pool topology: 'auto' (live JAX runtime), "
                         "'N' (one group of N), or 'GxN' (G groups of N); "
                         "empty = no placement pool (legacy sharing)")
    ap.add_argument("--pressure-policy", default="none",
                    help="none | shrink | shrink-regrow[:min=N] — resize "
                         "SHARED_FRAME sessions under queued load "
                         "(needs --topology)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore sessions from --checkpoint-dir")
    args = ap.parse_args(argv)

    if args.pool:
        return serve_pool(args)

    cfg = _resolve_config(args.arch)
    model = Model(cfg, None)
    params = model.init(jax.random.key(args.seed))

    if args.adaptive_eval:
        stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                             batch=args.batch, seed=args.seed)
        t0 = time.time()
        mean, tau, stopped = adaptive_eval(
            model, params, stream, eps=args.eps, delta=args.delta,
            batch=args.batch, seq=args.seq)
        print(f"[serve] adaptive eval: mean loss = {mean:.4f} ± {args.eps} "
              f"(p ≥ {1-args.delta}) after {tau:.0f} samples "
              f"(stopped={stopped}, {time.time()-t0:.1f}s)")
        return 0

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.prompt_len,
                         batch=args.batch, seed=args.seed)
    prompts = stream.batch_at(jnp.int32(0))["tokens"]
    t0 = time.time()
    out = generate(model, params, prompts, args.gen)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"[serve] generated {n_new} tokens in {dt:.1f}s "
          f"({n_new/dt:.1f} tok/s); sample row: "
          f"{np.asarray(out[0, -args.gen:]).tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

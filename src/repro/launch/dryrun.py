import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

plus (single-pod only) two small *unrolled* layer-differencing compiles that
correct ``cost_analysis``'s count-scan-body-once behaviour (DESIGN.md §6).
Results land in ``benchmarks/results/dryrun/<cell>.json``.

Usage:
    python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
    python -m repro.launch.dryrun --all            # every applicable cell
    python -m repro.launch.dryrun --all --multipod # 2-pod mesh pass
"""

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _cost_dict(compiled, chips: int) -> dict:
    from repro.analysis.hlo import collective_bytes
    from repro.core.compat import cost_analysis
    ca = cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.get("total", 0)),
        "coll_detail": {k: v for k, v in coll.items()
                        if k not in ("total", "count")},
        "coll_count": coll.get("count", 0),
    }


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes
                          + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes
                          - ma.alias_size_in_bytes),
    }


def _lower_compile(cfg, shape, mesh, verbose=True, flags=None):
    from repro.launch.specs import input_specs
    from repro.launch.steps import step_for

    kwargs, shardings, rules, model = input_specs(cfg, shape, mesh,
                                                  flags=flags)
    step = step_for(model, shape.kind)
    order = list(kwargs)  # dict order matches step signatures
    args = tuple(kwargs[k] for k in order)
    in_sh = tuple(shardings[k] for k in order)
    # donation: train updates (params, opt_state) in place; decode updates
    # the cache in place — halves the resident footprint and lets XLA fuse
    # the cache one-hot update into the donated buffer.
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    t0 = time.time()
    from repro.core.compat import set_mesh
    with set_mesh(mesh):
        lowered = jax.jit(step, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    if verbose:
        print(f"  lowered {t_lower:.1f}s, compiled {t_compile:.1f}s")
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        from repro.core.compat import cost_analysis
        ca = cost_analysis(compiled)
        print(f"  cost_analysis: flops={ca.get('flops', 0):.4g} "
              f"bytes={ca.get('bytes accessed', 0):.4g}")
    return compiled, dict(t_lower=t_lower, t_compile=t_compile)


def _diff_variants(cfg):
    """(base_cfg, two_cfg[, extra]) unrolled variants for layer-differencing."""
    def rep(**kw):
        return dataclasses.replace(cfg, scan_layers=False, grad_accum=1, **kw)
    if cfg.family == "encdec":
        return [("base", rep(n_layers=1, enc_layers=1)),
                ("dec2", rep(n_layers=2, enc_layers=1)),
                ("enc2", rep(n_layers=1, enc_layers=2))]
    if cfg.family == "hybrid":
        return [("base", rep(n_layers=3)), ("two", rep(n_layers=6))]
    return [("base", rep(n_layers=1)), ("two", rep(n_layers=2))]


def _corrected_cost(cfg, shape, mesh, flags=None) -> dict:
    """Layer-differenced flops/bytes/coll_bytes for the full depth."""
    from repro.analysis.roofline import combine_layer_diff
    chips = mesh.devices.size
    costs = {}
    for tag, vcfg in _diff_variants(cfg):
        compiled, _ = _lower_compile(vcfg, shape, mesh, verbose=False,
                                     flags=flags)
        costs[tag] = _cost_dict(compiled, chips)
    keys = ("flops", "bytes", "coll_bytes")
    def pick(c):
        return {k: c[k] for k in keys}
    if cfg.family == "encdec":
        dec = {k: costs["dec2"][k] - costs["base"][k] for k in keys}
        enc = {k: costs["enc2"][k] - costs["base"][k] for k in keys}
        used_dec = cfg.n_layers if shape.kind != "prefill" else cfg.n_layers
        out = {k: costs["base"][k]
               + max(dec[k], 0.0) * (cfg.n_layers - 1)
               + max(enc[k], 0.0) * (cfg.enc_layers - 1) for k in keys}
        # decode never runs the encoder; enc diff is ~0 there by construction
        return out
    if cfg.family == "hybrid":
        per_unit = {k: (costs["two"][k] - costs["base"][k]) for k in keys}
        return {k: costs["base"][k]
                + max(per_unit[k], 0.0) * (cfg.n_layers - 3) / 3.0
                for k in keys}
    return combine_layer_diff(pick(costs["base"]), pick(costs["two"]),
                              cfg.n_layers)


OPTS = {
    # §Perf hillclimb configurations (dryrun --opt): explicit beyond-baseline
    # changes per arch; everything else inherits the baseline.
    # (sort dispatch was tried and REFUTED for the jit/GSPMD path — see
    # EXPERIMENTS.md §Perf iterations 1–2; kept in the code base behind
    # cfg.moe_dispatch="sort" as the shard_map-migration starting point.)
    "qwen3-moe-235b-a22b": dict(moe_group=128),  # capacity C 40→16: one-hot
                                                 # dispatch tensors ÷4
    "smollm-360m": dict(grad_accum=1),  # 256-row batch divides 256-way DP;
                                        # policy-level: dp_over_model
    "mistral-large-123b": dict(grad_accum=32),
    "internlm2-20b": dict(grad_accum=4),
}
OPT_FLAGS = {
    "smollm-360m": dict(dp_over_model=True, zero1=True),
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             with_diff: bool = True, out_dir: Path = RESULTS,
             opt: bool = False) -> dict:
    import dataclasses as _dc
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import default_flags
    from repro.models import SHAPES, cell_is_applicable, get_config
    from repro.analysis.roofline import roofline_terms, model_flops

    cfg = get_config(arch)
    flags = None
    if opt:
        cfg = _dc.replace(cfg, **OPTS.get(arch, {}))
        if arch in OPT_FLAGS:
            flags = _dc.replace(default_flags(cfg), **OPT_FLAGS[arch])
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}" + ("__opt" if opt else "")
    print(f"[dryrun] {cell}")
    ok, why = cell_is_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "applicable": ok, "skip_reason": why}
    if ok:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        compiled, times = _lower_compile(cfg, shape, mesh, flags=flags)
        rec["memory"] = _mem_dict(compiled)
        rec["raw_cost"] = _cost_dict(compiled, chips)
        rec["times"] = times
        rec["chips"] = chips
        rec["fits_16gb"] = rec["memory"]["peak_bytes"] <= 16 * 1024 ** 3
        if with_diff and not multi_pod:
            corrected = _corrected_cost(cfg, shape, mesh, flags=flags)
            rec["corrected_cost"] = corrected
            terms = roofline_terms(
                flops_per_dev=corrected["flops"],
                bytes_per_dev=corrected["bytes"],
                coll_bytes_per_dev=corrected["coll_bytes"],
                chips=chips, cfg=cfg, shape=shape)
            rec["roofline"] = terms.as_dict()
            print(f"  roofline: compute={terms.compute_s:.4f}s "
                  f"memory={terms.memory_s:.4f}s "
                  f"collective={terms.collective_s:.4f}s "
                  f"dominant={terms.dominant} "
                  f"useful={terms.useful_ratio:.2f}")
        rec["model_flops"] = model_flops(cfg, shape)
    else:
        print(f"  SKIP: {why}")
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-diff", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf hillclimb config for this arch")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose result JSON already exists")
    args = ap.parse_args()

    from repro.models import SHAPES, all_configs

    cells = []
    if args.all:
        for arch in sorted(all_configs()):
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        mesh_name = "2x16x16" if args.multipod else "16x16"
        suffix = "__opt" if args.opt else ""
        out = RESULTS / f"{arch}__{shape}__{mesh_name}{suffix}.json"
        if args.resume and out.exists():
            print(f"[dryrun] {out.stem} (cached)")
            continue
        try:
            run_cell(arch, shape, args.multipod, with_diff=not args.no_diff,
                     opt=args.opt)
        except Exception as e:  # noqa: BLE001 — record & continue
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
            RESULTS.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(
                {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "applicable": True, "error": repr(e)}, indent=1))
    if failures:
        print(f"\n{len(failures)} FAILED cells:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nall cells OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

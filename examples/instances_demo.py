"""The ADS instance layer end-to-end: run every registered workload under a
chosen strategy/world, or the full cross-strategy conformance sweep.

    PYTHONPATH=src python examples/instances_demo.py
    PYTHONPATH=src python examples/instances_demo.py --strategy indexed --world 4
    PYTHONPATH=src python examples/instances_demo.py --conformance
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="local",
                    choices=["lock", "barrier", "local", "shared", "indexed"])
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--instance", default=None,
                    help="run only this registered instance")
    ap.add_argument("--conformance", action="store_true",
                    help="full strategy × world invariant sweep instead")
    args = ap.parse_args()

    from repro.core.conformance import run_all, run_conformance
    from repro.core.instances import available_instances, run_instance

    names = [args.instance] if args.instance else list(available_instances())

    if args.conformance:
        reports = {n: run_conformance(n, seed=args.seed) for n in names} \
            if args.instance else run_all(seed=args.seed)
        bad = 0
        for rep in reports.values():
            print(rep.summary())
            bad += 0 if rep.ok else 1
        raise SystemExit(1 if bad else 0)

    for name in names:
        t0 = time.time()
        est, res, built = run_instance(name, strategy=args.strategy,
                                       world=args.world, seed=args.seed)
        err = float(np.max(np.abs(est - built.oracle))) \
            if np.all(np.isfinite(built.oracle)) else float("nan")
        print(f"{name:13s} [{args.strategy}/W={args.world}] "
              f"τ={res.num:6d} epochs={res.epochs:4d} "
              f"err={err:.4f} (ε={built.eps:.4f}) "
              f"wall={time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

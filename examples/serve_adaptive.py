"""Serving example: batched generation + the ADS engine estimating a serving
metric to (ε,δ) — "how good is this checkpoint?" answered with adaptive
sampling instead of a fixed eval sweep.

    PYTHONPATH=src python examples/serve_adaptive.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve as serve_mod


def main() -> None:
    print("[example] adaptive-query pool (epoch-granular scheduler):")
    serve_mod.main(["--pool", "--queries",
                    "wrs:local:2,reachability:shared:2:1", "--max-in-flight",
                    "2"])
    print("\n[example] placement-aware pool: disjoint leases + pressure "
          "(worker-slot accounting works even on one device):")
    serve_mod.main(["--pool", "--queries",
                    "reachability:shared:2,wrs:local:2:1",
                    "--max-in-flight", "4", "--topology", "2",
                    "--pressure-policy", "shrink:min=1"])
    print("\n[example] batched greedy generation:")
    serve_mod.main(["--arch", "smollm-360m-reduced", "--batch", "4",
                    "--prompt-len", "16", "--gen", "16"])
    print("\n[example] adaptive (ε,δ) metric estimation:")
    serve_mod.main(["--arch", "smollm-360m-reduced", "--adaptive-eval",
                    "--eps", "0.25", "--delta", "0.1", "--seq", "32",
                    "--batch", "4"])


if __name__ == "__main__":
    main()

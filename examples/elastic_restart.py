"""Fault-tolerance demo: train → preempt → restore onto a *different*
data-parallel layout (elastic rescale), verifying bit-identical parameters
and an identical data cursor.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataCursor, TokenStream
from repro.models import Model
from repro.optim.adamw import adamw_init
from repro.runtime import elastic_restore
import repro.configs.smollm_360m as sm


def main() -> None:
    cfg = sm.reduced()
    model = Model(cfg, None)
    params = model.init(jax.random.key(0))
    opt = adamw_init(params)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_write=False)
        cursor = DataCursor(step=17, seed=0)
        mgr.save({"params": params, "opt": opt}, 17, meta=cursor.as_meta())
        print(f"[elastic] saved at step 17 (simulated 'mesh A', dp=1)")

        # "new fleet": different dp layout — here a 1-device mesh with an
        # explicit sharding tree, exercising the global-slice restore path
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        shardings = jax.tree.map(
            lambda x: NamedSharding(mesh, P()), {"params": params, "opt": opt})
        out = elastic_restore(mgr, {"params": params, "opt": opt}, shardings)
        assert out is not None
        step, tree, meta = out
        cur2 = DataCursor.from_meta(meta)
        print(f"[elastic] restored step={step}, data cursor={cur2.step}")

        same = jax.tree.map(
            lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
            tree["params"], params)
        assert all(jax.tree.leaves(same)), "params differ after reshard!"
        assert cur2 == DataCursor(step=17, seed=0)

        # data replay across a shard-count change stays globally identical
        stream = TokenStream(vocab=cfg.vocab, seq_len=16, batch=8, seed=0)
        full = np.asarray(stream.batch_at(jnp.int32(17), 0, 1)["tokens"])
        parts = [np.asarray(stream.batch_at(jnp.int32(17), i, 4)["tokens"])
                 for i in range(4)]
        assert np.array_equal(full, np.concatenate(parts, 0))
        print("[elastic] data stream invariant across shard counts ✓")
        print("[elastic] bit-identical restore onto a new layout ✓")


if __name__ == "__main__":
    main()

"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps with the paper's adaptive-sampling engine controlling gradient
accumulation, plus checkpointing and deterministic data.

The default invocation is CPU-sized; ``--steps 300 --seq 128`` is the full
run (tens of minutes on this container).

    PYTHONPATH=src python examples/train_adaptive.py --steps 40
    PYTHONPATH=src python examples/train_adaptive.py --steps 300 --seq 128
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


from repro.models import ModelConfig
from repro.launch import train as train_mod
import repro.models.config as config_mod

# ~100M params: 12L, d=768, ff=2048, vocab 32k → 85M + 25M embeddings
LM100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv=4, d_ff=2048, vocab=32_000, remat="none", attn_chunk=4096)
config_mod.register(LM100M)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    n = LM100M.param_count()
    print(f"[example] lm-100m: {n/1e6:.0f}M params, adaptive accumulation on")
    rc = train_mod.main([
        "--arch", "lm-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--micro", str(args.micro), "--adaptive", "--rtol", "0.2",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "10",
    ])
    raise SystemExit(rc)


if __name__ == "__main__":
    main()

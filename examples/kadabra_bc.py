"""Full KADABRA case study: every parallelization strategy of the paper on a
chosen instance category, with accuracy versus the exact oracle and the
epoch/termination statistics that drive Figs. 2–3.

    PYTHONPATH=src python examples/kadabra_bc.py --kind er --n 300 --eps 0.05
    PYTHONPATH=src python examples/kadabra_bc.py --kind grid --world 8
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.frames import FrameStrategy
from repro.graphs import (KadabraParams, barabasi_albert, brandes_exact,
                          erdos_renyi, grid2d, preprocess, run_kadabra)


def build(kind: str, n: int, seed: int):
    if kind == "er":
        return erdos_renyi(n, 5 * n, seed=seed)
    if kind == "ba":
        return barabasi_albert(n, 3, seed=seed)
    if kind == "grid":
        side = int(n ** 0.5)
        return grid2d(side, side)
    raise SystemExit(f"unknown kind {kind}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="er", choices=["er", "ba", "grid"])
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-exact", action="store_true")
    args = ap.parse_args()

    g = build(args.kind, args.n, args.seed)
    print(f"instance: kind={args.kind} n={g.n} arcs={g.m_arcs}")
    t0 = time.time()
    pre = preprocess(g, args.eps, args.delta)
    print(f"preprocessing: VD ≤ {pre.vd_upper}, ω = {pre.omega:.0f} "
          f"({time.time()-t0:.1f}s)")
    exact = None if args.skip_exact else brandes_exact(g)

    params = KadabraParams(eps=args.eps, delta=args.delta, batch=32,
                           rounds_per_epoch=4)
    print(f"\n{'strategy':>9s} {'W':>3s} {'τ':>8s} {'epochs':>7s} "
          f"{'max err':>8s} {'time':>7s}")
    for strat in (FrameStrategy.LOCK, FrameStrategy.BARRIER,
                  FrameStrategy.LOCAL_FRAME, FrameStrategy.SHARED_FRAME,
                  FrameStrategy.INDEXED_FRAME):
        worlds = [1] if strat == FrameStrategy.LOCK else [args.world]
        for w in worlds:
            t0 = time.time()
            btilde, st, _ = run_kadabra(g, params, strategy=strat, world=w,
                                        seed=args.seed, pre=pre)
            dt = time.time() - t0
            tau = float(np.asarray(st.total.num).reshape(-1)[0])
            ep = int(np.asarray(st.epoch).reshape(-1)[0])
            err = "-" if exact is None else \
                f"{np.abs(btilde - exact).max():8.4f}"
            print(f"{strat.value:>9s} {w:3d} {tau:8.0f} {ep:7d} "
                  f"{err:>8s} {dt:6.1f}s")


if __name__ == "__main__":
    main()

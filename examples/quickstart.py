"""Quickstart: the paper's algorithm in ~30 lines of user code.

Approximates betweenness centrality on a synthetic social graph with the
epoch-based local-frame algorithm (4 parallel workers), compares against the
exact Brandes oracle, prints the top-10 vertices.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.frames import FrameStrategy
from repro.graphs import KadabraParams, brandes_exact, erdos_renyi, run_kadabra


def main() -> None:
    g = erdos_renyi(n=200, m_edges=800, seed=7)
    print(f"graph: n={g.n}, arcs={g.m_arcs}")

    params = KadabraParams(eps=0.05, delta=0.1, batch=32, rounds_per_epoch=4)
    btilde, state, pre = run_kadabra(
        g, params, strategy=FrameStrategy.LOCAL_FRAME, world=4, seed=0)
    tau = float(np.asarray(state.total.num).reshape(-1)[0])
    print(f"adaptive sampling stopped after τ = {tau:.0f} samples "
          f"(ω cap was {pre.omega:.0f})")

    exact = brandes_exact(g)
    err = np.abs(btilde - exact).max()
    print(f"max |b̃ − b| = {err:.4f}  (ε = {params.eps}) "
          f"{'OK' if err <= params.eps else 'MISS'}")

    top = np.argsort(-btilde)[:10]
    print("\n top-10 vertices by approximate BC:")
    print(f" {'vertex':>7s} {'b̃(v)':>9s} {'exact':>9s}")
    for v in top:
        print(f" {v:7d} {btilde[v]:9.5f} {exact[v]:9.5f}")


if __name__ == "__main__":
    main()
